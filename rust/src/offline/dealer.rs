//! PRG-simulated trusted dealer.
//!
//! Both parties hold the same dealer seed and deterministically expand
//! identical correlated randomness; each keeps only its own share. This
//! models a trusted third party distributing triples out-of-band (the
//! paper: "this step ... can be prepared in advance as an offline phase,
//! using either cryptography-based methods or a trusted third party").
//! Protocol communication: zero. The [`crate::ss::triples::Ledger`]
//! still records consumption so benches can price the material as if it
//! had been produced by the OT generator.
//!
//! ## Fork-per-draw derivation (the parallel-prefill contract)
//!
//! Every draw forks one child PRG off the shared dealer stream (two
//! cheap parent draws) and expands the item entirely from that child.
//! The fork sequence is the only state the draws share, so:
//!
//! * a batch draw ([`crate::ss::triples::TripleSource::mat_triples`]
//!   etc.) forks all children **sequentially** — identical stream
//!   consumption to the same single draws — and then expands the
//!   children on up to `threads` workers via
//!   [`crate::runtime::pool`]: material is bit-identical for any
//!   thread count, and a party that prefills in parallel stays
//!   consistent with a peer drawing one triple at a time;
//! * the expensive part of a party-1 matrix triple (the `U·V` product)
//!   can itself run row-parallel without touching the stream.

use crate::ring::matrix::Mat;
use crate::runtime::pool;
use crate::ss::triples::{
    bit_words, last_word_mask, AuthMatTriple, BitTriple, DaBits, Ledger, MatTriple, TripleSource,
    VecTriple,
};
use crate::util::error::Result;
use crate::util::prng::Prg;

/// Domain-separation labels for the per-draw child forks (one per
/// material kind; the parent stream position provides uniqueness).
const LBL_MAT: u64 = 0x4D41_5452;
const LBL_VEC: u64 = 0x5645_4354;
const LBL_BIT: u64 = 0x4249_5454;
const LBL_DAB: u64 = 0x4441_4249;
/// MAC-authenticated matrix triples (malicious tier).
const LBL_AMT: u64 = 0x414D_5452;

/// Salt for the MAC-key derivation stream (independent of the dealer's
/// triple stream, so arming malicious security never shifts the
/// semi-honest material and existing transcripts stay byte-identical).
const MAC_KEY_SALT: u128 = 0xA1FA_u128 << 96;

/// The full MAC key α the simulated dealer holds (forced odd: an odd α
/// makes `α·Δ ≠ 0` for any non-zero additive error Δ with a lone bit,
/// matching the channel ledger's odd-coefficient rule).
fn mac_key(seed: u128) -> u64 {
    Prg::new(seed ^ MAC_KEY_SALT).next_u64() | 1
}

/// This party's additive share of the global MAC key α
/// (`mac_key_share(s, 0) + mac_key_share(s, 1) = α`, α odd). Pass the
/// same `seed` as [`Dealer::new`]; the share is what a run hands to
/// [`crate::net::Chan::enable_mac`]. The derivation stream is separate
/// from the triple stream, so semi-honest material is untouched.
pub fn mac_key_share(seed: u128, party: usize) -> u64 {
    assert!(party < 2);
    let mut prg = Prg::new(seed ^ MAC_KEY_SALT);
    let alpha = prg.next_u64() | 1;
    let r = prg.next_u64();
    if party == 0 {
        r
    } else {
        alpha.wrapping_sub(r)
    }
}

/// One party's endpoint of the simulated dealer.
pub struct Dealer {
    prg: Prg,
    party: usize,
    ledger: Ledger,
    /// The raw construction seed, kept for MAC-key derivation
    /// ([`mac_key`]) on authenticated draws.
    seed: u128,
}

/// Expand one matrix triple from a child stream. `inner_threads`
/// parallelizes the party-1 `U·V` product (the dominant cost of a large
/// triple); it never touches the stream, so results are thread-count
/// independent.
fn mat_triple_from(
    prg: &mut Prg,
    party: usize,
    m: usize,
    k: usize,
    n: usize,
    inner_threads: usize,
) -> MatTriple {
    // Both parties expand the *same* stream: full U, V, then share-0s.
    let u = Mat::random(m, k, prg);
    let v = Mat::random(k, n, prg);
    let u0 = Mat::random(m, k, prg);
    let v0 = Mat::random(k, n, prg);
    let z0 = Mat::random(m, n, prg);
    if party == 0 {
        MatTriple { u: u0, v: v0, z: z0 }
    } else {
        let z = pool::matmul_with(inner_threads, &u, &v);
        MatTriple { u: u.sub(&u0), v: v.sub(&v0), z: z.sub(&z0) }
    }
}

/// Expand one MAC-authenticated matrix triple: the base triple plus
/// additive shares of `α·U`, `α·V`, `α·Z`. The simulated dealer knows α
/// (both parties derive it from the shared seed, exactly as they expand
/// the full masks) — that is the trusted-dealer MAC model; online, each
/// party only ever handles its own share and its own α-share.
fn auth_mat_triple_from(
    prg: &mut Prg,
    party: usize,
    alpha: u64,
    m: usize,
    k: usize,
    n: usize,
    inner_threads: usize,
) -> AuthMatTriple {
    let u = Mat::random(m, k, prg);
    let v = Mat::random(k, n, prg);
    let u0 = Mat::random(m, k, prg);
    let v0 = Mat::random(k, n, prg);
    let z0 = Mat::random(m, n, prg);
    let mu0 = Mat::random(m, k, prg);
    let mv0 = Mat::random(k, n, prg);
    let mz0 = Mat::random(m, n, prg);
    if party == 0 {
        AuthMatTriple {
            base: MatTriple { u: u0, v: v0, z: z0 },
            mac_u: mu0,
            mac_v: mv0,
            mac_z: mz0,
        }
    } else {
        let z = pool::matmul_with(inner_threads, &u, &v);
        AuthMatTriple {
            mac_u: u.scale(alpha).sub(&mu0),
            mac_v: v.scale(alpha).sub(&mv0),
            mac_z: z.scale(alpha).sub(&mz0),
            base: MatTriple { u: u.sub(&u0), v: v.sub(&v0), z: z.sub(&z0) },
        }
    }
}

fn vec_triple_from(prg: &mut Prg, party: usize, n: usize) -> VecTriple {
    let u = prg.u64s(n);
    let v = prg.u64s(n);
    let u0 = prg.u64s(n);
    let v0 = prg.u64s(n);
    let z0 = prg.u64s(n);
    if party == 0 {
        VecTriple { u: u0, v: v0, z: z0 }
    } else {
        let u1: Vec<u64> = u.iter().zip(&u0).map(|(a, b)| a.wrapping_sub(*b)).collect();
        let v1: Vec<u64> = v.iter().zip(&v0).map(|(a, b)| a.wrapping_sub(*b)).collect();
        let z1: Vec<u64> =
            (0..n).map(|i| u[i].wrapping_mul(v[i]).wrapping_sub(z0[i])).collect();
        VecTriple { u: u1, v: v1, z: z1 }
    }
}

fn bit_triple_from(prg: &mut Prg, party: usize, n: usize) -> BitTriple {
    let w = bit_words(n);
    let a = prg.u64s(w);
    let b = prg.u64s(w);
    let a0 = prg.u64s(w);
    let b0 = prg.u64s(w);
    let c0 = prg.u64s(w);
    if party == 0 {
        BitTriple { a: a0, b: b0, c: c0, n }
    } else {
        let a1: Vec<u64> = a.iter().zip(&a0).map(|(x, y)| x ^ y).collect();
        let b1: Vec<u64> = b.iter().zip(&b0).map(|(x, y)| x ^ y).collect();
        let c1: Vec<u64> = (0..w).map(|i| (a[i] & b[i]) ^ c0[i]).collect();
        BitTriple { a: a1, b: b1, c: c1, n }
    }
}

fn dabits_from(prg: &mut Prg, party: usize, n: usize) -> DaBits {
    let w = bit_words(n);
    // Full bit vector r, then party-0's boolean and arithmetic pads.
    let r = prg.u64s(w);
    let b0 = prg.u64s(w);
    let a0 = prg.u64s(n);
    if party == 0 {
        let mut bool_words = b0;
        if let Some(last) = bool_words.last_mut() {
            *last &= last_word_mask(n);
        }
        DaBits { n, bool_words, arith: a0 }
    } else {
        let mut bool_words: Vec<u64> = r.iter().zip(&b0).map(|(x, y)| x ^ y).collect();
        if let Some(last) = bool_words.last_mut() {
            *last &= last_word_mask(n);
        }
        let arith: Vec<u64> = (0..n)
            .map(|i| ((r[i / 64] >> (i % 64)) & 1).wrapping_sub(a0[i]))
            .collect();
        DaBits { n, bool_words, arith }
    }
}

impl Dealer {
    /// `seed` must match across the two parties; `party` ∈ {0, 1}.
    pub fn new(seed: u128, party: usize) -> Self {
        assert!(party < 2);
        Dealer { prg: Prg::new(seed ^ 0xD0_1E_55), party, ledger: Ledger::default(), seed }
    }

    /// Fork the per-item child streams for a batch — strictly
    /// sequential, so stream consumption is independent of how the
    /// expansion is later scheduled.
    fn children(&mut self, label: u64, count: usize) -> Vec<Prg> {
        (0..count).map(|_| self.prg.fork(label)).collect()
    }

    /// Master-stream position (drawn `u64` lanes). The master PRG is
    /// only ever consumed by [`Prg::fork`] (two lanes per draw), so this
    /// single word plus the [`Ledger`] is the dealer's complete
    /// checkpointable state.
    pub fn position(&self) -> u64 {
        self.prg.position()
    }

    /// Rebuild a dealer mid-stream: same `(seed, party)` as the original,
    /// fast-forwarded to `position` with the accounted `ledger` restored.
    /// Subsequent draws are bit-identical to the uninterrupted dealer's.
    pub fn restore(seed: u128, party: usize, position: u64, ledger: Ledger) -> Self {
        let mut d = Dealer::new(seed, party);
        d.prg.skip_to(position);
        d.ledger = ledger;
        d
    }
}

impl TripleSource for Dealer {
    fn mat_triple(&mut self, m: usize, k: usize, n: usize) -> MatTriple {
        self.ledger.mat_triples += 1;
        self.ledger.mat_triple_elems += (m * k + k * n + m * n) as u64;
        let mut child = self.prg.fork(LBL_MAT);
        // Inline draws (no prefill) parallelize the U·V product itself.
        mat_triple_from(&mut child, self.party, m, k, n, pool::global_threads())
    }

    fn auth_mat_triple(&mut self, m: usize, k: usize, n: usize) -> Result<AuthMatTriple> {
        // MAC limbs double the per-component material; priced as such.
        self.ledger.mat_triples += 1;
        self.ledger.mat_triple_elems += (2 * (m * k + k * n + m * n)) as u64;
        let alpha = mac_key(self.seed);
        let mut child = self.prg.fork(LBL_AMT);
        Ok(auth_mat_triple_from(&mut child, self.party, alpha, m, k, n, pool::global_threads()))
    }

    fn vec_triple(&mut self, n: usize) -> VecTriple {
        self.ledger.vec_triple_lanes += n as u64;
        let mut child = self.prg.fork(LBL_VEC);
        vec_triple_from(&mut child, self.party, n)
    }

    fn bit_triple(&mut self, n: usize) -> BitTriple {
        self.ledger.bit_triple_lanes += n as u64;
        let mut child = self.prg.fork(LBL_BIT);
        bit_triple_from(&mut child, self.party, n)
    }

    fn dabits(&mut self, n: usize) -> DaBits {
        self.ledger.dabit_lanes += n as u64;
        let mut child = self.prg.fork(LBL_DAB);
        dabits_from(&mut child, self.party, n)
    }

    fn ledger(&self) -> Ledger {
        self.ledger
    }

    fn mat_triples(
        &mut self,
        m: usize,
        k: usize,
        n: usize,
        count: usize,
        threads: usize,
    ) -> Vec<MatTriple> {
        self.ledger.mat_triples += count as u64;
        self.ledger.mat_triple_elems += ((m * k + k * n + m * n) * count) as u64;
        let children = self.children(LBL_MAT, count);
        let party = self.party;
        // One worker per triple; the inner product stays sequential so a
        // batch of B triples uses ≤ threads workers total.
        pool::parallel_map(threads, &children, |_, child| {
            let mut prg = child.clone();
            mat_triple_from(&mut prg, party, m, k, n, 1)
        })
    }

    fn vec_triples(&mut self, lanes: &[usize], threads: usize) -> Vec<VecTriple> {
        for &n in lanes {
            self.ledger.vec_triple_lanes += n as u64;
        }
        let children = self.children(LBL_VEC, lanes.len());
        let party = self.party;
        pool::parallel_gen(threads, lanes.len(), |i| {
            let mut prg = children[i].clone();
            vec_triple_from(&mut prg, party, lanes[i])
        })
    }

    fn bit_triples(&mut self, lanes: &[usize], threads: usize) -> Vec<BitTriple> {
        for &n in lanes {
            self.ledger.bit_triple_lanes += n as u64;
        }
        let children = self.children(LBL_BIT, lanes.len());
        let party = self.party;
        pool::parallel_gen(threads, lanes.len(), |i| {
            let mut prg = children[i].clone();
            bit_triple_from(&mut prg, party, lanes[i])
        })
    }

    fn dabits_many(&mut self, lanes: &[usize], threads: usize) -> Vec<DaBits> {
        for &n in lanes {
            self.ledger.dabit_lanes += n as u64;
        }
        let children = self.children(LBL_DAB, lanes.len());
        let party = self.party;
        pool::parallel_gen(threads, lanes.len(), |i| {
            let mut prg = children[i].clone();
            dabits_from(&mut prg, party, lanes[i])
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mat_triples_reconstruct_to_products() {
        let mut d0 = Dealer::new(99, 0);
        let mut d1 = Dealer::new(99, 1);
        for (m, k, n) in [(1, 1, 1), (2, 3, 4), (5, 2, 5)] {
            let t0 = d0.mat_triple(m, k, n);
            let t1 = d1.mat_triple(m, k, n);
            let u = t0.u.add(&t1.u);
            let v = t0.v.add(&t1.v);
            let z = t0.z.add(&t1.z);
            assert_eq!(u.matmul(&v), z, "shape {m}x{k}x{n}");
        }
    }

    #[test]
    fn auth_mat_triples_reconstruct_with_valid_macs() {
        let mut d0 = Dealer::new(99, 0);
        let mut d1 = Dealer::new(99, 1);
        let alpha = mac_key_share(99, 0).wrapping_add(mac_key_share(99, 1));
        assert_eq!(alpha % 2, 1, "MAC key must be odd");
        for (m, k, n) in [(1, 1, 1), (2, 3, 4)] {
            let t0 = d0.auth_mat_triple(m, k, n).unwrap();
            let t1 = d1.auth_mat_triple(m, k, n).unwrap();
            let u = t0.base.u.add(&t1.base.u);
            let v = t0.base.v.add(&t1.base.v);
            let z = t0.base.z.add(&t1.base.z);
            assert_eq!(u.matmul(&v), z, "base triple {m}x{k}x{n}");
            assert_eq!(t0.mac_u.add(&t1.mac_u), u.scale(alpha), "mac_u {m}x{k}x{n}");
            assert_eq!(t0.mac_v.add(&t1.mac_v), v.scale(alpha), "mac_v {m}x{k}x{n}");
            assert_eq!(t0.mac_z.add(&t1.mac_z), z.scale(alpha), "mac_z {m}x{k}x{n}");
        }
    }

    #[test]
    fn auth_draws_keep_parties_consistent() {
        // Authenticated draws advance the shared fork sequence like any
        // other draw, so as long as both parties interleave them
        // identically (true by the symmetric-protocol construction),
        // subsequent plain draws still reconstruct. A dealer that never
        // draws auth material is bit-identical to the pre-MAC dealer,
        // which is what the pinned transcript goldens rely on.
        let mut d0 = Dealer::new(123, 0);
        let mut d1 = Dealer::new(123, 1);
        let _ = d0.auth_mat_triple(2, 2, 2).unwrap();
        let _ = d1.auth_mat_triple(2, 2, 2).unwrap();
        let t0 = d0.mat_triple(2, 3, 4);
        let t1 = d1.mat_triple(2, 3, 4);
        let u = t0.u.add(&t1.u);
        let v = t0.v.add(&t1.v);
        assert_eq!(u.matmul(&v), t0.z.add(&t1.z));
    }

    #[test]
    fn mac_key_shares_are_party_dependent_pads() {
        // Same seed → same α; different seeds → (overwhelmingly)
        // different keys; the reconstructed key is always odd.
        let a5 = mac_key_share(5, 0).wrapping_add(mac_key_share(5, 1));
        let a6 = mac_key_share(6, 0).wrapping_add(mac_key_share(6, 1));
        assert_ne!(a5, a6);
        assert_eq!(a5 & 1, 1);
        assert_eq!(a6 & 1, 1);
    }

    #[test]
    fn vec_triples_reconstruct() {
        let mut d0 = Dealer::new(5, 0);
        let mut d1 = Dealer::new(5, 1);
        let t0 = d0.vec_triple(100);
        let t1 = d1.vec_triple(100);
        for i in 0..100 {
            let u = t0.u[i].wrapping_add(t1.u[i]);
            let v = t0.v[i].wrapping_add(t1.v[i]);
            let z = t0.z[i].wrapping_add(t1.z[i]);
            assert_eq!(u.wrapping_mul(v), z, "lane {i}");
        }
    }

    #[test]
    fn bit_triples_reconstruct() {
        let mut d0 = Dealer::new(6, 0);
        let mut d1 = Dealer::new(6, 1);
        let t0 = d0.bit_triple(200);
        let t1 = d1.bit_triple(200);
        for i in 0..t0.a.len() {
            let a = t0.a[i] ^ t1.a[i];
            let b = t0.b[i] ^ t1.b[i];
            let c = t0.c[i] ^ t1.c[i];
            assert_eq!(a & b, c, "word {i}");
        }
    }

    #[test]
    fn dabits_agree_across_worlds() {
        let mut d0 = Dealer::new(12, 0);
        let mut d1 = Dealer::new(12, 1);
        let n = 70;
        let a = d0.dabits(n);
        let b = d1.dabits(n);
        for i in 0..n {
            let bool_bit = ((a.bool_words[i / 64] ^ b.bool_words[i / 64]) >> (i % 64)) & 1;
            let arith_bit = a.arith[i].wrapping_add(b.arith[i]);
            assert_eq!(bool_bit, arith_bit, "lane {i}: XOR and additive worlds disagree");
            assert!(arith_bit <= 1, "lane {i}: not a bit");
        }
        // Tail lanes beyond n are masked off in the boolean packing.
        let tail = a.bool_words[1] ^ b.bool_words[1];
        assert_eq!(tail >> (n - 64), 0, "tail bits must be masked");
    }

    #[test]
    fn shares_look_independent_of_secret() {
        // Party 0's share stream must not depend on which party asks —
        // i.e. dealer outputs for party 0 are pure PRG output.
        let mut a = Dealer::new(7, 0);
        let mut b = Dealer::new(7, 0);
        let ta = a.mat_triple(2, 2, 2);
        let tb = b.mat_triple(2, 2, 2);
        assert_eq!(ta.u, tb.u);
        assert_eq!(ta.z, tb.z);
    }

    #[test]
    fn ledger_counts_material() {
        let mut d = Dealer::new(8, 0);
        d.mat_triple(2, 3, 4);
        d.vec_triple(10);
        d.bit_triple(65);
        let l = d.ledger();
        assert_eq!(l.mat_triples, 1);
        assert_eq!(l.mat_triple_elems, (6 + 12 + 8) as u64);
        assert_eq!(l.vec_triple_lanes, 10);
        assert_eq!(l.bit_triple_lanes, 65);
    }

    #[test]
    fn batch_draws_match_single_draws_exactly() {
        // Stream equivalence: N batch items == N single draws, for every
        // material kind, so mixed prefill/inline parties stay consistent.
        let mut single = Dealer::new(31, 1);
        let mut batch = Dealer::new(31, 1);
        let singles: Vec<MatTriple> = (0..3).map(|_| single.mat_triple(3, 2, 4)).collect();
        let batched = batch.mat_triples(3, 2, 4, 3, 4);
        for (s, b) in singles.iter().zip(&batched) {
            assert_eq!(s.u, b.u);
            assert_eq!(s.v, b.v);
            assert_eq!(s.z, b.z);
        }
        let sv: Vec<VecTriple> = [5usize, 9].iter().map(|&n| single.vec_triple(n)).collect();
        let bv = batch.vec_triples(&[5, 9], 4);
        assert_eq!(sv[1].z, bv[1].z);
        let sb: Vec<BitTriple> = [64usize, 7].iter().map(|&n| single.bit_triple(n)).collect();
        let bb = batch.bit_triples(&[64, 7], 4);
        assert_eq!(sb[0].c, bb[0].c);
        let sd: Vec<DaBits> = [10usize, 3].iter().map(|&n| single.dabits(n)).collect();
        let bd = batch.dabits_many(&[10, 3], 4);
        assert_eq!(sd[0].arith, bd[0].arith);
        assert_eq!(single.ledger(), batch.ledger(), "ledgers must agree");
    }

    #[test]
    fn restore_resumes_the_exact_stream() {
        let mut live = Dealer::new(0x5EED, 1);
        live.mat_triple(2, 3, 4);
        live.vec_triple(9);
        live.dabits(17);
        let pos = live.position();
        let led = live.ledger();
        let mut back = Dealer::restore(0x5EED, 1, pos, led);
        assert_eq!(back.position(), pos);
        assert_eq!(back.ledger(), led);
        let a = live.mat_triple(3, 2, 2);
        let b = back.mat_triple(3, 2, 2);
        assert_eq!(a.u, b.u);
        assert_eq!(a.z, b.z);
        assert_eq!(live.bit_triple(70).c, back.bit_triple(70).c);
        assert_eq!(live.ledger(), back.ledger());
    }

    #[test]
    fn batch_draws_are_thread_count_independent() {
        for threads in [1usize, 2, 4, 8] {
            let mut d = Dealer::new(77, 1);
            let mats = d.mat_triples(4, 3, 2, 5, threads);
            let vecs = d.vec_triples(&[8, 16, 8], threads);
            let mut base = Dealer::new(77, 1);
            let bm = base.mat_triples(4, 3, 2, 5, 1);
            let bv = base.vec_triples(&[8, 16, 8], 1);
            for (a, b) in mats.iter().zip(&bm) {
                assert_eq!(a.z, b.z, "threads = {threads}");
            }
            for (a, b) in vecs.iter().zip(&bv) {
                assert_eq!(a.z, b.z, "threads = {threads}");
            }
        }
    }
}
