//! The replenished offline material bank behind the scoring service.
//!
//! Training consumes its offline material once; serving consumes it
//! forever. The [`MaterialBank`] turns the one-shot
//! [`TripleStore::prefill`] into a **stocked service**: it is planned
//! with the per-batch [`Demand`] of one scored micro-batch (uniform
//! across batches — see [`crate::serve::scorer::Scorer`]), prefabricates
//! `prefab_batches` batches of triples/daBits up front, serves score
//! calls strictly FIFO from that stock, and replenishes `refill_batches`
//! more whenever the stock drops below `low_water`. Every quantity is
//! exactly accounted:
//!
//! ```text
//! prefabricated + replenished − consumed == stock   (always)
//! ```
//!
//! and a correctly-planned bank keeps the underlying store's
//! `misses == 0` — every online draw hits prefabricated material, which
//! is the paper's "pre-compute almost all cryptographic operations"
//! split pushed from one training job to a stream of scoring jobs.
//! Bank bytes are priced from the planned demand
//! ([`MaterialBank::per_batch_mat_triple_bytes`] /
//! [`MaterialBank::stocked_mat_triple_bytes`]), and generation traffic
//! via [`crate::offline::pricing`] on [`MaterialBank::served_demand`].
//!
//! Concurrency model: the in-process serve loop drains its request
//! queue in arrival order, so material draws are strictly sequential —
//! FIFO fairness is inherited from [`TripleStore`]'s per-shape FIFO
//! queues (a request batch can never consume a later batch's stock).

use super::store::{Demand, TripleStore};
use crate::resume::BankCounters;
use crate::ss::triples::TripleSource;
use crate::util::error::{Error, Result};

/// Stocking policy for a [`MaterialBank`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BankConfig {
    /// Batches of material fabricated up front.
    pub prefab_batches: usize,
    /// Replenish when the stock drops strictly below this many batches.
    pub low_water: usize,
    /// Batches fabricated per replenishment.
    pub refill_batches: usize,
}

impl Default for BankConfig {
    fn default() -> Self {
        BankConfig { prefab_batches: 8, low_water: 2, refill_batches: 4 }
    }
}

/// A stocked, replenished triple store serving per-batch score calls.
pub struct MaterialBank<S: TripleSource> {
    store: TripleStore<S>,
    per_batch: Demand,
    cfg: BankConfig,
    /// Worker threads for prefabrication/replenishment fan-out (the
    /// stocked material is bit-identical for any value).
    threads: usize,
    stock: usize,
    /// Batches fabricated up front (== `cfg.prefab_batches`).
    pub prefabricated: usize,
    /// Batches added by replenishment so far.
    pub replenished: usize,
    /// Batches checked out so far.
    pub consumed: usize,
    /// Replenishment events so far.
    pub replenish_events: usize,
    /// Checkouts that had to replenish **synchronously on the scoring
    /// path** (a bank-dry stall): the batch that triggered the refill
    /// paid its fabrication latency inline. 0 means the stocking policy
    /// kept fabrication entirely off the online path — the gateway's
    /// sharded bank ([`crate::serve::gateway`]) gets there with
    /// background replenishers; this in-process bank surfaces the count
    /// so `ServeReport` can show what the policy cost.
    pub stalls: u64,
}

impl<S: TripleSource> MaterialBank<S> {
    /// Plan a bank from one batch's demand and fabricate the initial
    /// stock (the serving offline phase proper), single-threaded.
    pub fn new(inner: S, per_batch: Demand, cfg: BankConfig) -> MaterialBank<S> {
        MaterialBank::new_par(inner, per_batch, cfg, 1)
    }

    /// [`MaterialBank::new`] with prefabrication and every later
    /// replenishment fanned out across up to `threads` workers. Stocked
    /// material is bit-identical to the single-threaded bank's (the
    /// batch-draw contract of [`crate::ss::triples::TripleSource`]), so
    /// the two parties may even use different thread counts.
    pub fn new_par(
        inner: S,
        per_batch: Demand,
        cfg: BankConfig,
        threads: usize,
    ) -> MaterialBank<S> {
        assert!(cfg.refill_batches > 0, "a bank must refill by at least one batch");
        let threads = threads.max(1);
        let mut store = TripleStore::new(inner);
        store.prefill_par(&per_batch.repeat(cfg.prefab_batches), threads);
        MaterialBank {
            store,
            per_batch,
            cfg,
            threads,
            stock: cfg.prefab_batches,
            prefabricated: cfg.prefab_batches,
            replenished: 0,
            consumed: 0,
            replenish_events: 0,
            stalls: 0,
        }
    }

    /// Rebuild a bank to the exact state a prior bank reached after the
    /// checkpointed counters' worth of checkouts ([`BankCounters`] from
    /// a [`crate::resume::ServeState`]). `inner` must be a fresh
    /// generator with the original seed.
    ///
    /// Draws never touch the generator — only fabrication does — so
    /// replaying the fabrications back-to-back (the prefab, then every
    /// replenishment) consumes the dealer stream exactly as the original
    /// interleaved run did; draining the consumed batches then pops the
    /// same FIFO front the original checkouts handed out. The surviving
    /// stock is **bit-identical**, and the served-demand ledger is
    /// re-recorded along the way. Counters inconsistent with the
    /// stocking policy (a stale or foreign checkpoint) are a typed
    /// error, never a panic.
    pub fn restore(
        inner: S,
        per_batch: Demand,
        cfg: BankConfig,
        threads: usize,
        counters: &BankCounters,
    ) -> Result<MaterialBank<S>> {
        if cfg.refill_batches == 0 {
            return Err(Error::Config("a bank must refill by at least one batch".into()));
        }
        let prefab = counters.prefabricated as usize;
        let replenished = counters.replenished as usize;
        let consumed = counters.consumed as usize;
        let events = counters.replenish_events as usize;
        if prefab != cfg.prefab_batches
            || replenished != events * cfg.refill_batches
            || consumed > prefab + replenished
        {
            return Err(Error::Config(format!(
                "bank restore: checkpoint counters (prefab {prefab}, replenished {replenished} \
                 over {events} events, consumed {consumed}) are inconsistent with the stocking \
                 policy {cfg:?}"
            )));
        }
        let threads = threads.max(1);
        let mut store = TripleStore::new(inner);
        store.prefill_par(&per_batch.repeat(prefab), threads);
        for _ in 0..events {
            store.prefill_par(&per_batch.repeat(cfg.refill_batches), threads);
        }
        let drained = per_batch.repeat(consumed);
        for &((m, k, n), count) in &drained.mats {
            for _ in 0..count {
                let _ = store.mat_triple(m, k, n);
            }
        }
        for &n in &drained.vec_chunks {
            let _ = store.vec_triple(n);
        }
        for &n in &drained.bit_chunks {
            let _ = store.bit_triple(n);
        }
        for &n in &drained.dabit_chunks {
            let _ = store.dabits(n);
        }
        if store.misses != 0 {
            return Err(Error::Config(
                "bank restore: draining the consumed batches missed prefabricated stock — the \
                 checkpoint's per-batch demand does not match its counters"
                    .into(),
            ));
        }
        Ok(MaterialBank {
            store,
            per_batch,
            cfg,
            threads,
            stock: prefab + replenished - consumed,
            prefabricated: prefab,
            replenished,
            consumed,
            replenish_events: events,
            stalls: counters.stalls,
        })
    }

    /// Check out one batch of material: consumes one batch of stock and
    /// returns the store to draw it from (pass as the score call's
    /// [`TripleSource`]). Replenishes first if the stock is empty
    /// (cold-start or `low_water = 0`), and again after consumption once
    /// the stock drops below the low-water mark. Replenishment runs
    /// **synchronously inside this call** — the in-process serve loop
    /// charges the stall to the batch that triggered it (a real
    /// deployment would refill from a background fabricator instead);
    /// the low-water margin exists so the refill never races an empty
    /// queue.
    pub fn checkout(&mut self) -> &mut TripleStore<S> {
        let mut stalled = false;
        if self.stock == 0 {
            self.replenish();
            stalled = true;
        }
        self.stock -= 1;
        self.consumed += 1;
        if self.stock < self.cfg.low_water {
            self.replenish();
            stalled = true;
        }
        // One stall per checkout even if both triggers fired: the batch
        // paid inline fabrication latency once, however many refills ran.
        if stalled {
            self.stalls += 1;
        }
        &mut self.store
    }

    /// Fabricate `refill_batches` more batches into stock.
    fn replenish(&mut self) {
        self.store
            .prefill_par(&self.per_batch.repeat(self.cfg.refill_batches), self.threads);
        self.stock += self.cfg.refill_batches;
        self.replenished += self.cfg.refill_batches;
        self.replenish_events += 1;
    }

    /// Batches currently in stock.
    pub fn stock(&self) -> usize {
        self.stock
    }

    /// The planned per-batch demand.
    pub fn per_batch_demand(&self) -> &Demand {
        &self.per_batch
    }

    /// Online draws that missed the prefabricated stock (0 for a
    /// correctly planned bank).
    pub fn misses(&self) -> u64 {
        self.store.misses
    }

    /// Every request actually served (for OT-based pricing of the
    /// serving offline phase).
    pub fn served_demand(&self) -> &Demand {
        &self.store.demand
    }

    /// Matrix-triple bytes of one planned batch.
    pub fn per_batch_mat_triple_bytes(&self) -> u64 {
        self.per_batch.mat_triple_bytes()
    }

    /// Matrix-triple bytes currently held in stock.
    pub fn stocked_mat_triple_bytes(&self) -> u64 {
        self.per_batch.mat_triple_bytes() * self.stock as u64
    }

    /// The exact stock ledger: `prefabricated + replenished − consumed
    /// == stock`. Maintained by construction; exposed so callers can
    /// assert it end-to-end.
    pub fn accounting_balances(&self) -> bool {
        self.prefabricated + self.replenished == self.consumed + self.stock
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::offline::dealer::Dealer;
    use crate::ss::triples::TripleSource;

    fn batch_demand() -> Demand {
        let mut d = Demand::default();
        d.mat(4, 2, 3);
        d.vec_lanes(8);
        d.dabit_lanes(4);
        d
    }

    /// Draw exactly one batch's material from a checked-out store.
    fn draw_batch(store: &mut dyn TripleSource) {
        let _ = store.mat_triple(4, 2, 3);
        let _ = store.vec_triple(8);
        let _ = store.dabits(4);
    }

    #[test]
    fn accounting_balances_across_replenishment() {
        let cfg = BankConfig { prefab_batches: 5, low_water: 2, refill_batches: 4 };
        let mut bank = MaterialBank::new(Dealer::new(1, 0), batch_demand(), cfg);
        assert_eq!(bank.stock(), 5);
        for i in 0..10 {
            draw_batch(bank.checkout());
            assert!(bank.accounting_balances(), "after batch {i}");
        }
        assert_eq!(bank.consumed, 10);
        // Stock path: 5→4→3→2→1(+4)→… replenishes whenever < 2.
        assert!(bank.replenish_events >= 1, "10 > 5 batches must force a replenishment");
        assert_eq!(
            bank.prefabricated + bank.replenished - bank.consumed,
            bank.stock(),
            "ledger must balance"
        );
        assert_eq!(bank.misses(), 0, "every draw must hit prefabricated stock");
    }

    #[test]
    fn cold_start_with_zero_prefab_still_serves() {
        let cfg = BankConfig { prefab_batches: 0, low_water: 0, refill_batches: 2 };
        let mut bank = MaterialBank::new(Dealer::new(2, 0), batch_demand(), cfg);
        assert_eq!(bank.stock(), 0);
        draw_batch(bank.checkout());
        assert_eq!(bank.misses(), 0, "emergency replenish must cover the draw");
        assert!(bank.accounting_balances());
    }

    #[test]
    fn stalls_count_inline_replenishments_once_per_checkout() {
        // prefab 3, low_water 0: the only replenish trigger is a dry
        // bank, so exactly every 2nd checkout past the prefab stalls.
        let cfg = BankConfig { prefab_batches: 3, low_water: 0, refill_batches: 2 };
        let mut bank = MaterialBank::new(Dealer::new(7, 0), batch_demand(), cfg);
        for _ in 0..3 {
            draw_batch(bank.checkout());
        }
        assert_eq!(bank.stalls, 0, "prefab stock absorbs the first batches");
        draw_batch(bank.checkout()); // dry → inline refill → stall
        assert_eq!(bank.stalls, 1);
        draw_batch(bank.checkout()); // still one in stock
        assert_eq!(bank.stalls, 1);
        draw_batch(bank.checkout()); // dry again
        assert_eq!(bank.stalls, 2);
        assert!(bank.accounting_balances());
    }

    #[test]
    fn stocked_bytes_track_stock() {
        let cfg = BankConfig { prefab_batches: 3, low_water: 0, refill_batches: 1 };
        let mut bank = MaterialBank::new(Dealer::new(3, 0), batch_demand(), cfg);
        let per = bank.per_batch_mat_triple_bytes();
        assert_eq!(per, batch_demand().mat_triple_bytes());
        assert_eq!(bank.stocked_mat_triple_bytes(), 3 * per);
        draw_batch(bank.checkout());
        assert_eq!(bank.stocked_mat_triple_bytes(), 2 * per);
    }

    #[test]
    fn parallel_bank_is_bit_identical_to_sequential() {
        // Prefab AND replenishment run through the fan-out path; every
        // checked-out share must match the single-threaded bank exactly.
        let cfg = BankConfig { prefab_batches: 2, low_water: 1, refill_batches: 2 };
        let mut seq = MaterialBank::new(Dealer::new(9, 1), batch_demand(), cfg);
        let mut par = MaterialBank::new_par(Dealer::new(9, 1), batch_demand(), cfg, 4);
        for batch in 0..6 {
            let s = seq.checkout();
            let a_mat = s.mat_triple(4, 2, 3);
            let a_vec = s.vec_triple(8);
            let a_dab = s.dabits(4);
            let p = par.checkout();
            let b_mat = p.mat_triple(4, 2, 3);
            let b_vec = p.vec_triple(8);
            let b_dab = p.dabits(4);
            assert_eq!(a_mat.z, b_mat.z, "batch {batch}");
            assert_eq!(a_vec.z, b_vec.z, "batch {batch}");
            assert_eq!(a_dab.arith, b_dab.arith, "batch {batch}");
        }
        assert_eq!(seq.misses() + par.misses(), 0);
        assert_eq!(seq.replenish_events, par.replenish_events);
    }

    #[test]
    fn restored_bank_hands_out_bit_identical_stock() {
        // Run an original bank across a replenishment boundary, snapshot
        // its counters, restore a twin from a fresh dealer, and check
        // that every subsequent draw matches word-for-word — the
        // property serve-batch resume rests on.
        let cfg = BankConfig { prefab_batches: 3, low_water: 1, refill_batches: 2 };
        let mut orig = MaterialBank::new(Dealer::new(42, 1), batch_demand(), cfg);
        for _ in 0..4 {
            draw_batch(orig.checkout());
        }
        let counters = BankCounters {
            prefabricated: orig.prefabricated as u64,
            replenished: orig.replenished as u64,
            consumed: orig.consumed as u64,
            replenish_events: orig.replenish_events as u64,
            stalls: orig.stalls,
        };
        let mut twin =
            MaterialBank::restore(Dealer::new(42, 1), batch_demand(), cfg, 2, &counters).unwrap();
        assert_eq!(twin.stock(), orig.stock());
        assert_eq!(twin.served_demand(), orig.served_demand());
        assert!(twin.accounting_balances());
        for batch in 0..3 {
            let a = orig.checkout();
            let (am, av, ad) = (a.mat_triple(4, 2, 3), a.vec_triple(8), a.dabits(4));
            let b = twin.checkout();
            let (bm, bv, bd) = (b.mat_triple(4, 2, 3), b.vec_triple(8), b.dabits(4));
            assert_eq!(am.z, bm.z, "batch {batch}");
            assert_eq!(av.z, bv.z, "batch {batch}");
            assert_eq!(ad.arith, bd.arith, "batch {batch}");
        }
        assert_eq!(orig.misses() + twin.misses(), 0);
    }

    #[test]
    fn restore_rejects_inconsistent_counters() {
        let cfg = BankConfig { prefab_batches: 2, low_water: 1, refill_batches: 2 };
        // consumed exceeds everything ever fabricated → typed error.
        let bad = BankCounters {
            prefabricated: 2,
            replenished: 0,
            consumed: 9,
            replenish_events: 0,
            stalls: 0,
        };
        let err = MaterialBank::restore(Dealer::new(5, 0), batch_demand(), cfg, 1, &bad)
            .err()
            .map(|e| e.to_string())
            .unwrap_or_default();
        assert!(err.contains("inconsistent"), "{err}");
    }

    #[test]
    fn banks_stay_consistent_across_parties() {
        // Both parties' banks must hand out matching triple shares in
        // FIFO order even across a replenishment boundary.
        let cfg = BankConfig { prefab_batches: 1, low_water: 1, refill_batches: 1 };
        let mut b0 = MaterialBank::new(Dealer::new(4, 0), batch_demand(), cfg);
        let mut b1 = MaterialBank::new(Dealer::new(4, 1), batch_demand(), cfg);
        for _ in 0..3 {
            let t0 = b0.checkout().vec_triple(8);
            let t1 = b1.checkout().vec_triple(8);
            for i in 0..8 {
                let u = t0.u[i].wrapping_add(t1.u[i]);
                let v = t0.v[i].wrapping_add(t1.v[i]);
                let z = t0.z[i].wrapping_add(t1.z[i]);
                assert_eq!(u.wrapping_mul(v), z);
            }
        }
        assert_eq!(b0.misses() + b1.misses(), 0);
    }
}
