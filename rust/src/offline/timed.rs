//! Timing wrapper around a triple source.
//!
//! When a protocol runs "integrated" (no prefill), triple generation
//! happens inline; wrapping the generator in [`TimedSource`] separates
//! the data-independent generation time from the data-dependent online
//! time in a single pass — the accounting behind the online/offline
//! split in every bench.

use crate::ss::triples::{
    AuthMatTriple, BitTriple, DaBits, Ledger, MatTriple, TripleSource, VecTriple,
};
use crate::util::error::Result;
use std::time::Instant;

/// Accumulates wall-clock seconds spent inside the inner source.
pub struct TimedSource<S: TripleSource> {
    inner: S,
    /// Cumulative generation time in seconds.
    pub secs: f64,
}

impl<S: TripleSource> TimedSource<S> {
    /// Wrap a generator with a zeroed clock.
    pub fn new(inner: S) -> Self {
        TimedSource { inner, secs: 0.0 }
    }

    /// Unwrap the inner generator.
    pub fn into_inner(self) -> S {
        self.inner
    }

    /// Borrow the inner generator (e.g. to read a dealer's stream
    /// position for a checkpoint without consuming the wrapper).
    pub fn source(&self) -> &S {
        &self.inner
    }
}

impl<S: TripleSource> TripleSource for TimedSource<S> {
    fn mat_triple(&mut self, m: usize, k: usize, n: usize) -> MatTriple {
        let t0 = Instant::now();
        let t = self.inner.mat_triple(m, k, n);
        self.secs += t0.elapsed().as_secs_f64();
        t
    }

    fn auth_mat_triple(&mut self, m: usize, k: usize, n: usize) -> Result<AuthMatTriple> {
        let t0 = Instant::now();
        let t = self.inner.auth_mat_triple(m, k, n);
        self.secs += t0.elapsed().as_secs_f64();
        t
    }

    fn vec_triple(&mut self, n: usize) -> VecTriple {
        let t0 = Instant::now();
        let t = self.inner.vec_triple(n);
        self.secs += t0.elapsed().as_secs_f64();
        t
    }

    fn bit_triple(&mut self, n: usize) -> BitTriple {
        let t0 = Instant::now();
        let t = self.inner.bit_triple(n);
        self.secs += t0.elapsed().as_secs_f64();
        t
    }

    fn dabits(&mut self, n: usize) -> DaBits {
        let t0 = Instant::now();
        let t = self.inner.dabits(n);
        self.secs += t0.elapsed().as_secs_f64();
        t
    }

    fn ledger(&self) -> Ledger {
        self.inner.ledger()
    }

    // Batch draws delegate to the inner source's (possibly parallel)
    // batch path so prefill fan-out is timed as one generation span.
    fn mat_triples(
        &mut self,
        m: usize,
        k: usize,
        n: usize,
        count: usize,
        threads: usize,
    ) -> Vec<MatTriple> {
        let t0 = Instant::now();
        let t = self.inner.mat_triples(m, k, n, count, threads);
        self.secs += t0.elapsed().as_secs_f64();
        t
    }

    fn vec_triples(&mut self, lanes: &[usize], threads: usize) -> Vec<VecTriple> {
        let t0 = Instant::now();
        let t = self.inner.vec_triples(lanes, threads);
        self.secs += t0.elapsed().as_secs_f64();
        t
    }

    fn bit_triples(&mut self, lanes: &[usize], threads: usize) -> Vec<BitTriple> {
        let t0 = Instant::now();
        let t = self.inner.bit_triples(lanes, threads);
        self.secs += t0.elapsed().as_secs_f64();
        t
    }

    fn dabits_many(&mut self, lanes: &[usize], threads: usize) -> Vec<DaBits> {
        let t0 = Instant::now();
        let t = self.inner.dabits_many(lanes, threads);
        self.secs += t0.elapsed().as_secs_f64();
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::offline::dealer::Dealer;

    #[test]
    fn records_time_and_delegates() {
        let mut ts = TimedSource::new(Dealer::new(1, 0));
        let _ = ts.mat_triple(8, 8, 8);
        let _ = ts.vec_triple(100);
        assert!(ts.secs > 0.0);
        assert_eq!(ts.ledger().mat_triples, 1);
    }
}
