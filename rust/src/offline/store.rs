//! Pre-computed triple store: the deployable form of the offline phase.
//!
//! A [`Demand`] describes the material a known workload will consume
//! (K-means shapes are static given n, d, k, t — see
//! [`crate::kmeans::secure`]). [`TripleStore::prefill`] draws everything
//! from an underlying generator ahead of time; the online phase then pops
//! FIFO with zero generation cost, which is exactly the paper's
//! online/offline split. Requests that miss the pre-computed stock fall
//! through to the inner source and are counted (a correctly-sized demand
//! keeps `misses == 0`; asserted in tests and benches).

use crate::ss::triples::{
    AuthMatTriple, BitTriple, DaBits, Ledger, MatTriple, TripleSource, VecTriple,
};
use crate::util::error::Result;
use std::collections::{BTreeMap, VecDeque};

/// Offline material demand for one protocol run.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct Demand {
    /// (m, k, n) → how many matrix triples of that shape.
    pub mats: Vec<((usize, usize, usize), usize)>,
    /// Elementwise triple lanes, in request-sized chunks.
    pub vec_chunks: Vec<usize>,
    /// Boolean triple lanes, in request-sized chunks.
    pub bit_chunks: Vec<usize>,
    /// daBit lanes, in request-sized chunks.
    pub dabit_chunks: Vec<usize>,
}

impl Demand {
    /// Record one matrix triple of shape `(m, k, n)`.
    pub fn mat(&mut self, m: usize, k: usize, n: usize) {
        if let Some(e) = self.mats.iter_mut().find(|(s, _)| *s == (m, k, n)) {
            e.1 += 1;
        } else {
            self.mats.push(((m, k, n), 1));
        }
    }

    /// Record one elementwise-triple chunk of `n` lanes.
    pub fn vec_lanes(&mut self, n: usize) {
        self.vec_chunks.push(n);
    }

    /// Record one boolean-triple chunk of `n` lanes.
    pub fn bit_lanes(&mut self, n: usize) {
        self.bit_chunks.push(n);
    }

    /// Record one daBit chunk of `n` lanes.
    pub fn dabit_lanes(&mut self, n: usize) {
        self.dabit_chunks.push(n);
    }

    /// Repeat this demand `times` times (e.g. per-iteration demand × t).
    pub fn repeat(&self, times: usize) -> Demand {
        let mut out = Demand::default();
        for _ in 0..times {
            for ((m, k, n), c) in &self.mats {
                for _ in 0..*c {
                    out.mat(*m, *k, *n);
                }
            }
            out.vec_chunks.extend_from_slice(&self.vec_chunks);
            out.bit_chunks.extend_from_slice(&self.bit_chunks);
            out.dabit_chunks.extend_from_slice(&self.dabit_chunks);
        }
        out
    }

    /// A cheap cursor for later [`Demand::delta_since`] calls: per-shape
    /// matrix counts (a handful of entries) plus chunk-vector lengths —
    /// O(shapes), unlike cloning the whole demand whose chunk vectors
    /// grow with every gate request.
    ///
    /// # Examples
    ///
    /// Snapshot, accumulate, and diff — the per-step attribution idiom
    /// of the secure K-means driver:
    ///
    /// ```
    /// use ppkmeans::offline::store::Demand;
    ///
    /// let mut demand = Demand::default();
    /// demand.mat(8, 4, 2);
    /// let before = demand.mark();          // O(shapes) snapshot
    /// demand.mat(8, 4, 2);                 // the step's own draws…
    /// demand.vec_lanes(16);
    /// let step = demand.delta_since(&before);
    /// assert_eq!(step.mats, vec![((8, 4, 2), 1)]); // only post-mark counts
    /// assert_eq!(step.vec_chunks, vec![16]);
    /// ```
    pub fn mark(&self) -> DemandMark {
        DemandMark {
            mats: self.mats.clone(),
            vec_len: self.vec_chunks.len(),
            bit_len: self.bit_chunks.len(),
            dabit_len: self.dabit_chunks.len(),
        }
    }

    /// Demand accumulated since a [`Demand::mark`] snapshot (the mark
    /// must be a prefix of `self` in request order).
    pub fn delta_since(&self, before: &DemandMark) -> Demand {
        let mut out = Demand::default();
        for ((m, k, n), count) in &self.mats {
            let prev = before
                .mats
                .iter()
                .find(|(s, _)| s == &(*m, *k, *n))
                .map(|(_, c)| *c)
                .unwrap_or(0);
            for _ in prev..*count {
                out.mat(*m, *k, *n);
            }
        }
        out.vec_chunks = self.vec_chunks[before.vec_len..].to_vec();
        out.bit_chunks = self.bit_chunks[before.bit_len..].to_vec();
        out.dabit_chunks = self.dabit_chunks[before.dabit_len..].to_vec();
        out
    }

    /// Demand accumulated between two cumulative snapshots
    /// (`before` must be a prefix of `self` in request order).
    pub fn delta(&self, before: &Demand) -> Demand {
        self.delta_since(&before.mark())
    }

    /// Merge another demand into this one.
    pub fn extend(&mut self, other: &Demand) {
        for ((m, k, n), c) in &other.mats {
            for _ in 0..*c {
                self.mat(*m, *k, *n);
            }
        }
        self.vec_chunks.extend_from_slice(&other.vec_chunks);
        self.bit_chunks.extend_from_slice(&other.bit_chunks);
        self.dabit_chunks.extend_from_slice(&other.dabit_chunks);
    }

    /// Total bytes of matrix-triple material: a `(m, k, n)` triple holds
    /// `U (m×k)`, `V (k×n)` and `Z (m×n)` ring elements of 8 bytes.
    pub fn mat_triple_bytes(&self) -> u64 {
        self.mats
            .iter()
            .map(|&((m, k, n), count)| ((m * k + k * n + m * n) * 8 * count) as u64)
            .sum()
    }

    /// Bytes of the single largest matrix triple — the live-memory peak
    /// one staged product forces a party to hold. Row tiling bounds this
    /// by the tile size instead of n.
    pub fn peak_mat_triple_bytes(&self) -> u64 {
        self.mats
            .iter()
            .map(|&((m, k, n), _)| ((m * k + k * n + m * n) * 8) as u64)
            .max()
            .unwrap_or(0)
    }
}

/// A cheap cumulative-demand cursor (see [`Demand::mark`]).
#[derive(Debug, Clone)]
pub struct DemandMark {
    mats: Vec<((usize, usize, usize), usize)>,
    vec_len: usize,
    bit_len: usize,
    dabit_len: usize,
}

/// FIFO store over a fallback generator. Every stock — matrix triples by
/// shape, vector/bit/daBit chunks by **lane count** — is keyed, so a
/// draw order that differs from the prefill order (tiled vs monolithic
/// replay, interleaved steps) still hits as long as the multiset of
/// requests matches. (The seed code kept the chunk stocks in one global
/// FIFO and only served a front chunk of exactly the requested size:
/// one out-of-order draw left that chunk at the front forever, stranding
/// the entire remaining stock and mis-counting every later request as a
/// miss.)
pub struct TripleStore<S: TripleSource> {
    inner: S,
    // BTreeMap, not HashMap: stock ledgers are iterated for reports and
    // (in two-process runs) digested into transcripts, so their order
    // must be a function of the keys alone, never of a per-process
    // SipHash seed (ppkm-lint rule no-unordered-iteration).
    mats: BTreeMap<(usize, usize, usize), VecDeque<MatTriple>>,
    /// MAC-authenticated matrix triples (malicious tier), stocked by
    /// [`TripleStore::prefill_auth`]. Kept outside [`Demand`] — demands
    /// are checkpointed in resume artifacts and malicious runs reject
    /// resume, so authenticated demand never needs to round-trip.
    auth_mats: BTreeMap<(usize, usize, usize), VecDeque<AuthMatTriple>>,
    vecs: BTreeMap<usize, VecDeque<VecTriple>>,
    bits: BTreeMap<usize, VecDeque<BitTriple>>,
    dabits: BTreeMap<usize, VecDeque<DaBits>>,
    /// Requests that had to fall through to the inner source online.
    pub misses: u64,
    /// Every request seen (hit or miss) — replaying a protocol once with
    /// an empty store records the exact demand to prefill next time.
    pub demand: Demand,
}

impl<S: TripleSource> TripleStore<S> {
    /// Wrap a generator with empty stock (draws fall through and are
    /// recorded until [`TripleStore::prefill`] stocks the store).
    pub fn new(inner: S) -> Self {
        TripleStore {
            inner,
            mats: BTreeMap::new(),
            auth_mats: BTreeMap::new(),
            vecs: BTreeMap::new(),
            bits: BTreeMap::new(),
            dabits: BTreeMap::new(),
            misses: 0,
            demand: Demand::default(),
        }
    }

    /// Stock `count` MAC-authenticated matrix triples of one shape
    /// (malicious tier). Fails typed if the inner source cannot produce
    /// authenticated material.
    pub fn prefill_auth(&mut self, m: usize, k: usize, n: usize, count: usize) -> Result<()> {
        for _ in 0..count {
            let t = self.inner.auth_mat_triple(m, k, n)?;
            self.auth_mats.entry((m, k, n)).or_default().push_back(t);
        }
        Ok(())
    }

    /// Current matrix-triple stock as `((m, k, n), count)` pairs, in
    /// ascending shape order — the order is part of the contract (it
    /// feeds reports and transcript digests) and is guaranteed by the
    /// `BTreeMap` ledger regardless of prefill or draw order.
    pub fn stocked_mat_shapes(&self) -> Vec<((usize, usize, usize), usize)> {
        self.mats.iter().map(|(&shape, q)| (shape, q.len())).collect()
    }

    /// Current chunk stock (vector-triple, bit-triple, daBit) as
    /// `(lanes, count)` pairs per kind, in ascending lane order.
    pub fn stocked_chunks(&self) -> [Vec<(usize, usize)>; 3] {
        [
            self.vecs.iter().map(|(&n, q)| (n, q.len())).collect(),
            self.bits.iter().map(|(&n, q)| (n, q.len())).collect(),
            self.dabits.iter().map(|(&n, q)| (n, q.len())).collect(),
        ]
    }

    /// Generate all demanded material now (the offline phase proper),
    /// single-threaded. See [`TripleStore::prefill_par`] for the
    /// multi-core form; the stocked material is identical.
    pub fn prefill(&mut self, demand: &Demand) {
        self.prefill_par(demand, 1)
    }

    /// Generate all demanded material on up to `threads` workers via the
    /// source's batch draws ([`TripleSource::mat_triples`] and friends).
    /// The fabricated material is **bit-identical** for every `threads`
    /// value — the batch-draw contract — so parallel prefill changes
    /// wall-clock only, never a share.
    pub fn prefill_par(&mut self, demand: &Demand, threads: usize) {
        for ((m, k, n), count) in &demand.mats {
            let ts = self.inner.mat_triples(*m, *k, *n, *count, threads);
            self.mats.entry((*m, *k, *n)).or_default().extend(ts);
        }
        let vts = self.inner.vec_triples(&demand.vec_chunks, threads);
        for (&n, t) in demand.vec_chunks.iter().zip(vts) {
            self.vecs.entry(n).or_default().push_back(t);
        }
        let bts = self.inner.bit_triples(&demand.bit_chunks, threads);
        for (&n, t) in demand.bit_chunks.iter().zip(bts) {
            self.bits.entry(n).or_default().push_back(t);
        }
        let dts = self.inner.dabits_many(&demand.dabit_chunks, threads);
        for (&n, t) in demand.dabit_chunks.iter().zip(dts) {
            self.dabits.entry(n).or_default().push_back(t);
        }
    }

    /// Access the inner source (e.g. to read its offline meter).
    pub fn inner(&self) -> &S {
        &self.inner
    }

    /// Unwrap the inner source.
    pub fn into_inner(self) -> S {
        self.inner
    }
}

impl<S: TripleSource> TripleSource for TripleStore<S> {
    fn mat_triple(&mut self, m: usize, k: usize, n: usize) -> MatTriple {
        self.demand.mat(m, k, n);
        if let Some(q) = self.mats.get_mut(&(m, k, n)) {
            if let Some(t) = q.pop_front() {
                return t;
            }
        }
        self.misses += 1;
        self.inner.mat_triple(m, k, n)
    }

    fn auth_mat_triple(&mut self, m: usize, k: usize, n: usize) -> Result<AuthMatTriple> {
        if let Some(t) = self.auth_mats.get_mut(&(m, k, n)).and_then(|q| q.pop_front()) {
            return Ok(t);
        }
        // Fall through without bumping `misses`: authenticated material
        // is generated inline by design in integrated (no-prefill) runs,
        // and the semi-honest miss accounting that benches assert on
        // must not observe the malicious tier at all.
        self.inner.auth_mat_triple(m, k, n)
    }

    fn vec_triple(&mut self, n: usize) -> VecTriple {
        self.demand.vec_lanes(n);
        // Chunks are keyed by lane count: draws of the same size stay
        // FIFO, draws of different sizes never block each other.
        if let Some(t) = self.vecs.get_mut(&n).and_then(|q| q.pop_front()) {
            return t;
        }
        self.misses += 1;
        self.inner.vec_triple(n)
    }

    fn bit_triple(&mut self, n: usize) -> BitTriple {
        self.demand.bit_lanes(n);
        if let Some(t) = self.bits.get_mut(&n).and_then(|q| q.pop_front()) {
            return t;
        }
        self.misses += 1;
        self.inner.bit_triple(n)
    }

    fn dabits(&mut self, n: usize) -> DaBits {
        self.demand.dabit_lanes(n);
        if let Some(t) = self.dabits.get_mut(&n).and_then(|q| q.pop_front()) {
            return t;
        }
        self.misses += 1;
        self.inner.dabits(n)
    }

    fn ledger(&self) -> Ledger {
        self.inner.ledger()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::offline::dealer::Dealer;

    #[test]
    fn prefilled_requests_hit_the_store() {
        let mut demand = Demand::default();
        demand.mat(2, 3, 4);
        demand.mat(2, 3, 4);
        demand.vec_lanes(10);
        demand.bit_lanes(64);
        let mut store = TripleStore::new(Dealer::new(1, 0));
        store.prefill(&demand);
        let _ = store.mat_triple(2, 3, 4);
        let _ = store.mat_triple(2, 3, 4);
        let _ = store.vec_triple(10);
        let _ = store.bit_triple(64);
        assert_eq!(store.misses, 0);
        // One more of each → misses.
        let _ = store.mat_triple(2, 3, 4);
        assert_eq!(store.misses, 1);
    }

    #[test]
    fn auth_stock_serves_then_falls_through_without_misses() {
        let mut s0 = TripleStore::new(Dealer::new(21, 0));
        let mut s1 = TripleStore::new(Dealer::new(21, 1));
        s0.prefill_auth(2, 3, 4, 1).unwrap();
        s1.prefill_auth(2, 3, 4, 1).unwrap();
        // First draw hits the stock, second falls through to the dealer;
        // both must reconstruct against the peer and neither is a miss.
        for _ in 0..2 {
            let t0 = s0.auth_mat_triple(2, 3, 4).unwrap();
            let t1 = s1.auth_mat_triple(2, 3, 4).unwrap();
            let u = t0.base.u.add(&t1.base.u);
            let v = t0.base.v.add(&t1.base.v);
            assert_eq!(u.matmul(&v), t0.base.z.add(&t1.base.z));
        }
        assert_eq!(s0.misses, 0);
        assert_eq!(s1.misses, 0);
    }

    #[test]
    fn out_of_order_draws_do_not_poison_the_stock() {
        // Regression: the seed store served vec/bit/dabit chunks from one
        // global FIFO and only matched the front chunk's size, so a
        // single out-of-order draw stranded the entire remaining stock
        // and every later request (even exact-size ones) counted as a
        // miss. Keyed by lane count, any draw order of the demanded
        // multiset must be all hits.
        let mut demand = Demand::default();
        demand.vec_lanes(5);
        demand.vec_lanes(7);
        demand.bit_lanes(64);
        demand.bit_lanes(16);
        demand.dabit_lanes(9);
        demand.dabit_lanes(3);
        let mut store = TripleStore::new(Dealer::new(4, 0));
        store.prefill(&demand);
        // Draw everything in reverse of the demanded order.
        let t = store.vec_triple(7);
        assert_eq!(t.u.len(), 7, "served chunk must match the request");
        let t = store.vec_triple(5);
        assert_eq!(t.u.len(), 5);
        assert_eq!(store.bit_triple(16).n, 16);
        assert_eq!(store.bit_triple(64).n, 64);
        assert_eq!(store.dabits(3).n, 3);
        assert_eq!(store.dabits(9).n, 9);
        assert_eq!(store.misses, 0, "out-of-order draws must all hit");
        // The stock is now empty: one more of any size is a miss.
        let _ = store.vec_triple(5);
        assert_eq!(store.misses, 1);
    }

    #[test]
    fn stock_iteration_order_is_keyed_not_insertion_or_hash_order() {
        // Regression for the HashMap ledgers the seed used: iterating
        // stock must yield the same sequence in every process and for
        // every prefill order, or two-process transcript digests drift.
        let orders: [&[(usize, usize, usize)]; 3] = [
            &[(2, 3, 4), (1, 1, 1), (9, 2, 5)],
            &[(9, 2, 5), (2, 3, 4), (1, 1, 1)],
            &[(1, 1, 1), (9, 2, 5), (2, 3, 4)],
        ];
        let mut snapshots = Vec::new();
        for shapes in orders {
            let mut demand = Demand::default();
            for &(m, k, n) in shapes {
                demand.mat(m, k, n);
            }
            demand.vec_lanes(7);
            demand.vec_lanes(3);
            demand.bit_lanes(64);
            demand.dabit_lanes(9);
            demand.dabit_lanes(2);
            let mut store = TripleStore::new(Dealer::new(8, 0));
            store.prefill(&demand);
            snapshots.push((store.stocked_mat_shapes(), store.stocked_chunks()));
        }
        // Ascending key order, independent of the demand permutation.
        let want_mats = vec![((1, 1, 1), 1), ((2, 3, 4), 1), ((9, 2, 5), 1)];
        for (mats, chunks) in &snapshots {
            assert_eq!(mats, &want_mats);
            assert_eq!(chunks[0], vec![(3, 1), (7, 1)]);
            assert_eq!(chunks[1], vec![(64, 1)]);
            assert_eq!(chunks[2], vec![(2, 1), (9, 1)]);
        }
    }

    #[test]
    fn demand_mat_triple_byte_accounting() {
        let mut d = Demand::default();
        d.mat(2, 3, 4); // U 6 + V 12 + Z 8 = 26 elems = 208 bytes
        d.mat(2, 3, 4);
        d.mat(1, 1, 1); // 3 elems = 24 bytes
        assert_eq!(d.mat_triple_bytes(), 2 * 208 + 24);
        assert_eq!(d.peak_mat_triple_bytes(), 208);
        assert_eq!(Demand::default().peak_mat_triple_bytes(), 0);
    }

    #[test]
    fn store_matches_dealer_consistency_across_parties() {
        // Store on one side, bare dealer on the other: triples must still
        // reconstruct because prefill preserves draw order.
        let mut demand = Demand::default();
        demand.vec_lanes(5);
        let mut s0 = TripleStore::new(Dealer::new(3, 0));
        s0.prefill(&demand);
        let mut d1 = Dealer::new(3, 1);
        let t0 = s0.vec_triple(5);
        let t1 = d1.vec_triple(5);
        for i in 0..5 {
            let u = t0.u[i].wrapping_add(t1.u[i]);
            let v = t0.v[i].wrapping_add(t1.v[i]);
            let z = t0.z[i].wrapping_add(t1.z[i]);
            assert_eq!(u.wrapping_mul(v), z);
        }
    }

    #[test]
    fn demand_delta_with_empty_prefix_is_identity() {
        // delta(default) must return the whole demand, chunk-for-chunk.
        let mut d = Demand::default();
        d.mat(2, 3, 4);
        d.mat(2, 3, 4);
        d.vec_lanes(7);
        d.bit_lanes(64);
        d.dabit_lanes(9);
        let delta = d.delta(&Demand::default());
        assert_eq!(delta, d);
        // And delta against itself is empty.
        let empty = d.delta(&d);
        assert_eq!(empty, Demand::default());
    }

    #[test]
    fn demand_delta_counts_repeated_shapes() {
        // The same matrix shape requested before and after the snapshot
        // must only contribute the post-snapshot count to the delta.
        let mut before = Demand::default();
        before.mat(5, 5, 5);
        before.mat(1, 2, 3);
        let mut after = before.clone();
        after.mat(5, 5, 5);
        after.mat(5, 5, 5);
        after.vec_lanes(10);
        let delta = after.delta(&before);
        assert_eq!(delta.mats, vec![((5, 5, 5), 2)]);
        assert_eq!(delta.vec_chunks, vec![10]);
        assert!(delta.bit_chunks.is_empty());
        assert!(delta.dabit_chunks.is_empty());
    }

    #[test]
    fn mark_and_delta_since_match_full_clone_delta() {
        let mut d = Demand::default();
        d.mat(2, 3, 4);
        d.vec_lanes(7);
        let before_clone = d.clone();
        let mark = d.mark();
        d.mat(2, 3, 4);
        d.mat(5, 5, 5);
        d.bit_lanes(64);
        d.vec_lanes(9);
        assert_eq!(d.delta_since(&mark), d.delta(&before_clone));
        let delta = d.delta_since(&mark);
        assert_eq!(delta.mats, vec![((2, 3, 4), 1), ((5, 5, 5), 1)]);
        assert_eq!(delta.vec_chunks, vec![9]);
        assert_eq!(delta.bit_chunks, vec![64]);
    }

    #[test]
    fn demand_repeat_zero_times_is_empty() {
        let mut d = Demand::default();
        d.mat(1, 1, 1);
        d.dabit_lanes(3);
        assert_eq!(d.repeat(0), Demand::default());
    }

    #[test]
    fn prefilled_dabits_hit_the_store() {
        let mut demand = Demand::default();
        demand.dabit_lanes(16);
        let mut store = TripleStore::new(Dealer::new(2, 0));
        store.prefill(&demand);
        let _ = store.dabits(16);
        assert_eq!(store.misses, 0);
        let _ = store.dabits(16);
        assert_eq!(store.misses, 1);
    }

    #[test]
    fn demand_repeat_and_extend() {
        let mut d = Demand::default();
        d.mat(1, 2, 3);
        d.vec_lanes(7);
        let r = d.repeat(3);
        assert_eq!(r.mats[0].1, 3);
        assert_eq!(r.vec_chunks.len(), 3);
        let mut e = Demand::default();
        e.extend(&r);
        e.extend(&d);
        assert_eq!(e.mats[0].1, 4);
    }

    #[test]
    fn extend_after_mark_shows_only_the_extension_in_delta() {
        // The serving/bank path marks a demand, extends it with another
        // recorded demand, and expects delta_since to report exactly the
        // extension — counts per shape, chunks in request order.
        let mut d = Demand::default();
        d.mat(2, 3, 4);
        d.vec_lanes(5);
        let mark = d.mark();
        let mut other = Demand::default();
        other.mat(2, 3, 4); // existing shape: count bumps
        other.mat(9, 1, 1); // new shape
        other.bit_lanes(64);
        other.dabit_lanes(3);
        d.extend(&other);
        let delta = d.delta_since(&mark);
        assert_eq!(delta, other);
        // The merged totals reflect both halves.
        assert_eq!(d.mats, vec![((2, 3, 4), 2), ((9, 1, 1), 1)]);
    }

    #[test]
    fn zero_shape_demands_cost_zero_bytes_and_keep_peak_sane() {
        // Degenerate (zero-dimension) shapes can appear when a backend
        // stages an empty overlap; byte accounting must price exactly
        // the non-empty operands and an all-zero shape must cost 0.
        let mut d = Demand::default();
        d.mat(0, 0, 0); // U, V, Z all empty → 0 bytes
        d.mat(0, 5, 7); // only V (5×7) is non-empty → 280 bytes
        d.mat(4, 0, 2); // only Z (4×2) is non-empty → 64 bytes
        assert_eq!(d.mat_triple_bytes(), 280 + 64);
        assert_eq!(d.peak_mat_triple_bytes(), 280);
        // Extending a real demand with the degenerate one adds its bytes
        // but cannot displace a larger peak.
        let mut e = Demand::default();
        e.mat(4, 4, 4); // 48 elems = 384 bytes
        e.extend(&d);
        assert_eq!(e.peak_mat_triple_bytes(), 384);
        assert_eq!(e.mat_triple_bytes(), 384 + 280 + 64);
    }

    #[test]
    fn repeat_then_extend_equals_extending_repeatedly() {
        // bank.prefill(per_batch.repeat(n)) must be indistinguishable —
        // shape counts AND chunk order — from extending n times, which
        // is what the online phase's draws replay against.
        let mut per_batch = Demand::default();
        per_batch.mat(8, 3, 2);
        per_batch.mat(8, 3, 2);
        per_batch.vec_lanes(16);
        per_batch.bit_lanes(64);
        per_batch.dabit_lanes(8);
        let repeated = per_batch.repeat(3);
        let mut extended = Demand::default();
        for _ in 0..3 {
            extended.extend(&per_batch);
        }
        assert_eq!(repeated, extended);
        // And extending a marked copy then diffing recovers the tail.
        let mut grown = per_batch.clone();
        let mark = grown.mark();
        grown.extend(&per_batch);
        grown.extend(&per_batch);
        assert_eq!(grown.delta_since(&mark), per_batch.repeat(2));
        // Peak is invariant under repetition (counts change, shapes don't).
        assert_eq!(repeated.peak_mat_triple_bytes(), per_batch.peak_mat_triple_bytes());
        assert_eq!(
            repeated.mat_triple_bytes(),
            3 * per_batch.mat_triple_bytes(),
            "byte totals scale linearly with repeats"
        );
    }
}
