//! Pre-computed triple store: the deployable form of the offline phase.
//!
//! A [`Demand`] describes the material a known workload will consume
//! (K-means shapes are static given n, d, k, t — see
//! [`crate::kmeans::secure`]). [`TripleStore::prefill`] draws everything
//! from an underlying generator ahead of time; the online phase then pops
//! FIFO with zero generation cost, which is exactly the paper's
//! online/offline split. Requests that miss the pre-computed stock fall
//! through to the inner source and are counted (a correctly-sized demand
//! keeps `misses == 0`; asserted in tests and benches).

use crate::ss::triples::{BitTriple, DaBits, Ledger, MatTriple, TripleSource, VecTriple};
use std::collections::{HashMap, VecDeque};

/// Offline material demand for one protocol run.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct Demand {
    /// (m, k, n) → how many matrix triples of that shape.
    pub mats: Vec<((usize, usize, usize), usize)>,
    /// Elementwise triple lanes, in request-sized chunks.
    pub vec_chunks: Vec<usize>,
    /// Boolean triple lanes, in request-sized chunks.
    pub bit_chunks: Vec<usize>,
    /// daBit lanes, in request-sized chunks.
    pub dabit_chunks: Vec<usize>,
}

impl Demand {
    pub fn mat(&mut self, m: usize, k: usize, n: usize) {
        if let Some(e) = self.mats.iter_mut().find(|(s, _)| *s == (m, k, n)) {
            e.1 += 1;
        } else {
            self.mats.push(((m, k, n), 1));
        }
    }

    pub fn vec_lanes(&mut self, n: usize) {
        self.vec_chunks.push(n);
    }

    pub fn bit_lanes(&mut self, n: usize) {
        self.bit_chunks.push(n);
    }

    pub fn dabit_lanes(&mut self, n: usize) {
        self.dabit_chunks.push(n);
    }

    /// Repeat this demand `times` times (e.g. per-iteration demand × t).
    pub fn repeat(&self, times: usize) -> Demand {
        let mut out = Demand::default();
        for _ in 0..times {
            for ((m, k, n), c) in &self.mats {
                for _ in 0..*c {
                    out.mat(*m, *k, *n);
                }
            }
            out.vec_chunks.extend_from_slice(&self.vec_chunks);
            out.bit_chunks.extend_from_slice(&self.bit_chunks);
            out.dabit_chunks.extend_from_slice(&self.dabit_chunks);
        }
        out
    }

    /// Demand accumulated between two cumulative snapshots
    /// (`before` must be a prefix of `self` in request order).
    pub fn delta(&self, before: &Demand) -> Demand {
        let mut out = Demand::default();
        for ((m, k, n), count) in &self.mats {
            let prev = before
                .mats
                .iter()
                .find(|(s, _)| s == &(*m, *k, *n))
                .map(|(_, c)| *c)
                .unwrap_or(0);
            for _ in prev..*count {
                out.mat(*m, *k, *n);
            }
        }
        out.vec_chunks = self.vec_chunks[before.vec_chunks.len()..].to_vec();
        out.bit_chunks = self.bit_chunks[before.bit_chunks.len()..].to_vec();
        out.dabit_chunks = self.dabit_chunks[before.dabit_chunks.len()..].to_vec();
        out
    }

    /// Merge another demand into this one.
    pub fn extend(&mut self, other: &Demand) {
        for ((m, k, n), c) in &other.mats {
            for _ in 0..*c {
                self.mat(*m, *k, *n);
            }
        }
        self.vec_chunks.extend_from_slice(&other.vec_chunks);
        self.bit_chunks.extend_from_slice(&other.bit_chunks);
        self.dabit_chunks.extend_from_slice(&other.dabit_chunks);
    }
}

/// FIFO store over a fallback generator.
pub struct TripleStore<S: TripleSource> {
    inner: S,
    mats: HashMap<(usize, usize, usize), VecDeque<MatTriple>>,
    vecs: VecDeque<VecTriple>,
    bits: VecDeque<BitTriple>,
    dabits: VecDeque<DaBits>,
    /// Requests that had to fall through to the inner source online.
    pub misses: u64,
    /// Every request seen (hit or miss) — replaying a protocol once with
    /// an empty store records the exact demand to prefill next time.
    pub demand: Demand,
}

impl<S: TripleSource> TripleStore<S> {
    pub fn new(inner: S) -> Self {
        TripleStore {
            inner,
            mats: HashMap::new(),
            vecs: VecDeque::new(),
            bits: VecDeque::new(),
            dabits: VecDeque::new(),
            misses: 0,
            demand: Demand::default(),
        }
    }

    /// Generate all demanded material now (the offline phase proper).
    pub fn prefill(&mut self, demand: &Demand) {
        for ((m, k, n), count) in &demand.mats {
            for _ in 0..*count {
                let t = self.inner.mat_triple(*m, *k, *n);
                self.mats.entry((*m, *k, *n)).or_default().push_back(t);
            }
        }
        for &n in &demand.vec_chunks {
            let t = self.inner.vec_triple(n);
            self.vecs.push_back(t);
        }
        for &n in &demand.bit_chunks {
            let t = self.inner.bit_triple(n);
            self.bits.push_back(t);
        }
        for &n in &demand.dabit_chunks {
            let t = self.inner.dabits(n);
            self.dabits.push_back(t);
        }
    }

    /// Access the inner source (e.g. to read its offline meter).
    pub fn inner(&self) -> &S {
        &self.inner
    }

    pub fn into_inner(self) -> S {
        self.inner
    }
}

impl<S: TripleSource> TripleSource for TripleStore<S> {
    fn mat_triple(&mut self, m: usize, k: usize, n: usize) -> MatTriple {
        self.demand.mat(m, k, n);
        if let Some(q) = self.mats.get_mut(&(m, k, n)) {
            if let Some(t) = q.pop_front() {
                return t;
            }
        }
        self.misses += 1;
        self.inner.mat_triple(m, k, n)
    }

    fn vec_triple(&mut self, n: usize) -> VecTriple {
        self.demand.vec_lanes(n);
        // Chunks must be drawn in the same sizes they were demanded.
        if let Some(front) = self.vecs.front() {
            if front.u.len() == n {
                return self.vecs.pop_front().unwrap();
            }
        }
        self.misses += 1;
        self.inner.vec_triple(n)
    }

    fn bit_triple(&mut self, n: usize) -> BitTriple {
        self.demand.bit_lanes(n);
        if let Some(front) = self.bits.front() {
            if front.n == n {
                return self.bits.pop_front().unwrap();
            }
        }
        self.misses += 1;
        self.inner.bit_triple(n)
    }

    fn dabits(&mut self, n: usize) -> DaBits {
        self.demand.dabit_lanes(n);
        if let Some(front) = self.dabits.front() {
            if front.n == n {
                return self.dabits.pop_front().unwrap();
            }
        }
        self.misses += 1;
        self.inner.dabits(n)
    }

    fn ledger(&self) -> Ledger {
        self.inner.ledger()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::offline::dealer::Dealer;

    #[test]
    fn prefilled_requests_hit_the_store() {
        let mut demand = Demand::default();
        demand.mat(2, 3, 4);
        demand.mat(2, 3, 4);
        demand.vec_lanes(10);
        demand.bit_lanes(64);
        let mut store = TripleStore::new(Dealer::new(1, 0));
        store.prefill(&demand);
        let _ = store.mat_triple(2, 3, 4);
        let _ = store.mat_triple(2, 3, 4);
        let _ = store.vec_triple(10);
        let _ = store.bit_triple(64);
        assert_eq!(store.misses, 0);
        // One more of each → misses.
        let _ = store.mat_triple(2, 3, 4);
        assert_eq!(store.misses, 1);
    }

    #[test]
    fn store_matches_dealer_consistency_across_parties() {
        // Store on one side, bare dealer on the other: triples must still
        // reconstruct because prefill preserves draw order.
        let mut demand = Demand::default();
        demand.vec_lanes(5);
        let mut s0 = TripleStore::new(Dealer::new(3, 0));
        s0.prefill(&demand);
        let mut d1 = Dealer::new(3, 1);
        let t0 = s0.vec_triple(5);
        let t1 = d1.vec_triple(5);
        for i in 0..5 {
            let u = t0.u[i].wrapping_add(t1.u[i]);
            let v = t0.v[i].wrapping_add(t1.v[i]);
            let z = t0.z[i].wrapping_add(t1.z[i]);
            assert_eq!(u.wrapping_mul(v), z);
        }
    }

    #[test]
    fn demand_delta_with_empty_prefix_is_identity() {
        // delta(default) must return the whole demand, chunk-for-chunk.
        let mut d = Demand::default();
        d.mat(2, 3, 4);
        d.mat(2, 3, 4);
        d.vec_lanes(7);
        d.bit_lanes(64);
        d.dabit_lanes(9);
        let delta = d.delta(&Demand::default());
        assert_eq!(delta, d);
        // And delta against itself is empty.
        let empty = d.delta(&d);
        assert_eq!(empty, Demand::default());
    }

    #[test]
    fn demand_delta_counts_repeated_shapes() {
        // The same matrix shape requested before and after the snapshot
        // must only contribute the post-snapshot count to the delta.
        let mut before = Demand::default();
        before.mat(5, 5, 5);
        before.mat(1, 2, 3);
        let mut after = before.clone();
        after.mat(5, 5, 5);
        after.mat(5, 5, 5);
        after.vec_lanes(10);
        let delta = after.delta(&before);
        assert_eq!(delta.mats, vec![((5, 5, 5), 2)]);
        assert_eq!(delta.vec_chunks, vec![10]);
        assert!(delta.bit_chunks.is_empty());
        assert!(delta.dabit_chunks.is_empty());
    }

    #[test]
    fn demand_repeat_zero_times_is_empty() {
        let mut d = Demand::default();
        d.mat(1, 1, 1);
        d.dabit_lanes(3);
        assert_eq!(d.repeat(0), Demand::default());
    }

    #[test]
    fn prefilled_dabits_hit_the_store() {
        let mut demand = Demand::default();
        demand.dabit_lanes(16);
        let mut store = TripleStore::new(Dealer::new(2, 0));
        store.prefill(&demand);
        let _ = store.dabits(16);
        assert_eq!(store.misses, 0);
        let _ = store.dabits(16);
        assert_eq!(store.misses, 1);
    }

    #[test]
    fn demand_repeat_and_extend() {
        let mut d = Demand::default();
        d.mat(1, 2, 3);
        d.vec_lanes(7);
        let r = d.repeat(3);
        assert_eq!(r.mats[0].1, 3);
        assert_eq!(r.vec_chunks.len(), 3);
        let mut e = Demand::default();
        e.extend(&r);
        e.extend(&d);
        assert_eq!(e.mats[0].1, 4);
    }
}
