//! Analytic pricing of the OT-based offline phase.
//!
//! Large benches run with the (instant) simulated dealer; the cost the
//! paper reports for the offline phase is the *OT generation* cost. The
//! formulas below give the exact byte counts of our IKNP/Gilboa
//! implementation for a recorded [`Demand`]; per-OT wall-clock is
//! calibrated once by running the real generator on a small batch
//! ([`calibrate`]), and the bench extrapolates (documented in
//! EXPERIMENTS.md). The formulas are validated against the real
//! generator's measured traffic in `rust/tests/protocol_e2e.rs`.

use super::gilboa::OtTripleGen;
use super::store::Demand;
use crate::net::duplex_pair;
use crate::runtime::pool::run_pair;
use crate::ss::triples::TripleSource;
use crate::util::timer::{timed, Timer};

/// IKNP per-OT overhead: 128-bit column correction per OT (receiver) —
/// 16 bytes; sender ships two masked messages.
const IKNP_ROW_BYTES: u64 = 16;

/// Cost of one batch of `ots` OTs carrying `msg_bytes` messages
/// (both parties' traffic summed).
fn ot_batch_bytes(ots: u64, msg_bytes: u64) -> u64 {
    ots * (IKNP_ROW_BYTES + 2 * msg_bytes)
}

/// Exact offline traffic (bytes, both parties summed) for a demand,
/// matching [`OtTripleGen`]'s message layout.
pub fn offline_bytes(demand: &Demand) -> u64 {
    let mut total = 0u64;
    // Base OT setup: 2 × (λ+1) group elements of 192 bytes, both directions.
    total += 2 * (128 + 1) * 192;
    for ((m, k, n), count) in &demand.mats {
        // Per inner index t: 64·m OTs with n-element (8-byte) messages,
        // both cross directions.
        let per = 2 * (*k as u64) * ot_batch_bytes(64 * *m as u64, 8 * *n as u64);
        total += per * (*count as u64);
    }
    for &lanes in &demand.vec_chunks {
        // Two directions × 64 OTs/lane × 8-byte messages.
        total += 2 * ot_batch_bytes(64 * lanes as u64, 8);
    }
    for &lanes in &demand.bit_chunks {
        // Two directions × 1 OT/lane × 1-byte messages.
        total += 2 * ot_batch_bytes(lanes as u64, 1);
    }
    for &lanes in &demand.dabit_chunks {
        // One Gilboa direction × 64 OTs/lane × 8-byte messages.
        total += ot_batch_bytes(64 * lanes as u64, 8);
    }
    total
}

/// Measured per-unit generation costs (seconds).
#[derive(Debug, Clone, Copy)]
pub struct OtCalibration {
    /// Seconds per Gilboa OT (64 per vec-triple lane).
    pub secs_per_ot: f64,
    /// Seconds per boolean-triple lane.
    pub secs_per_bit_lane: f64,
    /// One-time base-OT setup seconds.
    pub setup_secs: f64,
}

/// Run the real OT generator on a small batch and measure unit costs.
pub fn calibrate() -> OtCalibration {
    let (c0, c1) = duplex_pair();
    let (cal, ()) = run_pair(
        move || {
            let t0 = Timer::started();
            let mut g = OtTripleGen::new(c0, 4242);
            let setup_secs = t0.secs();
            let (_, vec_secs) = timed(|| g.vec_triple(64)); // 2 × 64 × 64 OTs
            let (_, bit_secs) = timed(|| g.bit_triple(4096));
            OtCalibration {
                secs_per_ot: vec_secs / (2.0 * 64.0 * 64.0),
                secs_per_bit_lane: bit_secs / 4096.0,
                setup_secs,
            }
        },
        move || {
            let mut g = OtTripleGen::new(c1, 4242);
            let _ = g.vec_triple(64);
            let _ = g.bit_triple(4096);
        },
    );
    cal
}

/// Estimated offline generation wall-clock for a demand.
pub fn offline_secs(demand: &Demand, cal: &OtCalibration) -> f64 {
    let mut ots = 0f64;
    for ((m, k, _n), count) in &demand.mats {
        ots += (2 * 64 * m * k * count) as f64;
    }
    for &lanes in &demand.vec_chunks {
        ots += (2 * 64 * lanes) as f64;
    }
    for &lanes in &demand.dabit_chunks {
        ots += (64 * lanes) as f64;
    }
    let mut secs = cal.setup_secs + ots * cal.secs_per_ot;
    for &lanes in &demand.bit_chunks {
        secs += lanes as f64 * cal.secs_per_bit_lane;
    }
    secs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_grow_with_demand() {
        let mut d1 = Demand::default();
        d1.mat(10, 2, 3);
        let mut d2 = d1.clone();
        d2.mat(10, 2, 3);
        assert!(offline_bytes(&d2) > offline_bytes(&d1));
        let base = Demand::default();
        assert_eq!(offline_bytes(&base), 2 * 129 * 192);
    }

    #[test]
    fn formula_matches_real_generator_traffic() {
        // Run the real generator for a tiny demand and compare bytes.
        let mut demand = Demand::default();
        demand.mat(2, 1, 3);
        demand.vec_lanes(4);
        demand.bit_lanes(128);
        let d2 = demand.clone();
        let (c0, c1) = duplex_pair();
        let h = std::thread::spawn(move || {
            let mut g = OtTripleGen::new(c1, 99);
            for ((m, k, n), c) in &d2.mats {
                for _ in 0..*c {
                    let _ = g.mat_triple(*m, *k, *n);
                }
            }
            for &l in &d2.vec_chunks {
                let _ = g.vec_triple(l);
            }
            for &l in &d2.bit_chunks {
                let _ = g.bit_triple(l);
            }
            g.into_meter()
        });
        let mut g = OtTripleGen::new(c0, 99);
        for ((m, k, n), c) in &demand.mats {
            for _ in 0..*c {
                let _ = g.mat_triple(*m, *k, *n);
            }
        }
        for &l in &demand.vec_chunks {
            let _ = g.vec_triple(l);
        }
        for &l in &demand.bit_chunks {
            let _ = g.bit_triple(l);
        }
        let m0 = g.into_meter();
        let m1 = h.join().unwrap();
        let measured = m0.total().bytes_sent + m1.total().bytes_sent;
        let predicted = offline_bytes(&demand);
        // The formula captures message payloads; framing/correction
        // matrices round to 64-lane words, so allow 20% slack.
        let ratio = measured as f64 / predicted as f64;
        assert!((0.8..1.25).contains(&ratio), "measured {measured} predicted {predicted}");
    }
}
