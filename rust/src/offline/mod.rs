//! The data-independent offline phase (paper §4.1).
//!
//! Produces the correlated randomness (Beaver triples) the online phase
//! consumes. Two generators implement [`crate::ss::triples::TripleSource`]:
//!
//! * [`dealer::Dealer`] — a PRG-simulated trusted third party: both
//!   parties expand the same dealer seed, zero protocol communication.
//!   The paper explicitly allows this deployment ("using either
//!   cryptography-based methods or a trusted third party").
//! * [`gilboa::OtTripleGen`] — the cryptographic two-party path the
//!   paper benchmarks: Naor-Pinkas-style base OTs ([`baseot`]) bootstrap
//!   an IKNP OT extension ([`iknp`]), and Gilboa's product-sharing
//!   ([`gilboa`]) turns l OTs into one multiplication triple. This is
//!   what makes the offline phase expensive — exactly the cost the
//!   online/offline split hides from the data-dependent path.
//!
//! [`store::TripleStore`] pre-computes material for a known workload and
//! serves it FIFO, modelling a real deployment where the offline phase
//! runs overnight. [`bank::MaterialBank`] extends that one-shot prefill
//! into a **stocked service** for the scoring path: N batches
//! prefabricated up front, FIFO checkout per score call, automatic
//! replenishment below a low-water mark, exact stock accounting.

pub mod bank;
pub mod baseot;
pub mod dealer;
pub mod gilboa;
pub mod iknp;
pub mod pricing;
pub mod store;
pub mod timed;
