//! IKNP oblivious-transfer extension (Ishai-Kilian-Nissim-Petrank 2003).
//!
//! Stretches λ = 128 base OTs into millions of fast OTs using only a
//! block cipher and XOR — the workhorse behind OT-based triple
//! generation [17 in the paper]. Roles are reversed in the base phase:
//! the extension *sender* plays base-OT *receiver* with a random choice
//! vector `s`, the extension *receiver* plays base-OT sender with random
//! seed pairs.
//!
//! Per batch of m OTs with L-byte messages: the receiver transmits a
//! m×128-bit correction matrix; the sender transmits 2·m·L bytes of
//! masked messages.
//!
//! ## Fan-out
//!
//! The per-OT work — column-stream PRG expansion, the bit-matrix
//! transposition, and above all the correlation-robust hash per row key
//! — is pure local compute indexed by OT position, so both endpoints
//! shard it across [`IknpSender::set_threads`] /
//! [`IknpReceiver::set_threads`] workers via [`crate::runtime::pool`].
//! The frames on the wire are assembled in index order and are
//! **byte-identical** for any thread count; only wall-clock changes.

use super::baseot::{base_ot_recv, base_ot_send, OtGroup};
use crate::net::Chan;
use crate::runtime::pool;
use crate::util::hash::Hash256;
use crate::util::prng::Prg;

/// Security parameter: number of base OTs / matrix width.
pub const LAMBDA: usize = 128;

/// Sender endpoint of the OT extension.
pub struct IknpSender {
    /// s: the random choice vector used in the base phase.
    s: [bool; LAMBDA],
    /// PRGs seeded by the chosen base-OT keys (column streams).
    streams: Vec<Prg>,
    /// OT counter for domain separation.
    sent: u64,
    /// Worker threads for the per-OT hashing/transposition fan-out.
    threads: usize,
}

/// Receiver endpoint of the OT extension.
pub struct IknpReceiver {
    /// PRG pairs from the base phase (both seeds known to receiver).
    streams0: Vec<Prg>,
    streams1: Vec<Prg>,
    sent: u64,
    /// Worker threads for the per-OT hashing/transposition fan-out.
    threads: usize,
}

/// Correlation-robust hash: expand a 128-bit row key into an L-byte mask.
///
/// Only the digest's first 16 bytes seed the mask PRG — the second
/// [`Hash256`] lane is deliberately paid for anyway so the hash keeps
/// the drop-in SHA-256 shape (swap `util::hash` for hardware SHA-256 in
/// production without touching this call site).
fn h_mask(index: u64, q: u128, len: usize) -> Vec<u8> {
    let mut h = Hash256::new();
    h.update(index.to_le_bytes());
    h.update(q.to_le_bytes());
    let d = h.finalize();
    let mut seed = [0u8; 16];
    seed.copy_from_slice(&d[..16]);
    let mut prg = Prg::from_seed(seed);
    let mut out = vec![0u8; len];
    prg.fill_bytes(&mut out);
    out
}

fn xor_into(dst: &mut [u8], src: &[u8]) {
    for (d, s) in dst.iter_mut().zip(src) {
        *d ^= *s;
    }
}

/// Set up the sender endpoint (runs λ base OTs as base-receiver).
pub fn setup_sender(chan: &mut Chan, prg: &mut Prg) -> IknpSender {
    let group = OtGroup::rfc3526();
    let mut s = [false; LAMBDA];
    for b in s.iter_mut() {
        *b = prg.next_u64() & 1 == 1;
    }
    let keys = base_ot_recv(chan, &group, &s, prg);
    let streams = keys.into_iter().map(Prg::from_seed).collect();
    IknpSender { s, streams, sent: 0, threads: 1 }
}

/// Set up the receiver endpoint (runs λ base OTs as base-sender).
pub fn setup_receiver(chan: &mut Chan, prg: &mut Prg) -> IknpReceiver {
    let group = OtGroup::rfc3526();
    let keys = base_ot_send(chan, &group, LAMBDA, prg);
    let streams0 = keys.iter().map(|(k0, _)| Prg::from_seed(*k0)).collect();
    let streams1 = keys.iter().map(|(_, k1)| Prg::from_seed(*k1)).collect();
    IknpReceiver { streams0, streams1, sent: 0, threads: 1 }
}

impl IknpReceiver {
    /// Cap the local fan-out at `threads` workers (wire bytes are
    /// unchanged for any value).
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = threads.max(1);
    }

    /// Receive `choices.len()` OTs of `msg_len`-byte messages; returns
    /// the chosen message per OT.
    pub fn recv(&mut self, chan: &mut Chan, choices: &[bool], msg_len: usize) -> Vec<Vec<u8>> {
        let m = choices.len();
        let threads = self.threads;
        let words = (m + 63) / 64;
        // Choice bits packed.
        let mut r = vec![0u64; words];
        for (j, &c) in choices.iter().enumerate() {
            if c {
                r[j / 64] |= 1 << (j % 64);
            }
        }
        // Column streams: t_i = G(k0_i), u_i = t_i ^ G(k1_i) ^ r. Each
        // column's PRG advances exactly as it would sequentially (the
        // pool hands every worker a disjoint column range).
        let t_cols = pool::parallel_map_mut(threads, &mut self.streams0, |_, p| p.u64s(words));
        let g1_cols = pool::parallel_map_mut(threads, &mut self.streams1, |_, p| p.u64s(words));
        let mut u_payload = Vec::with_capacity(LAMBDA * words * 8);
        for i in 0..LAMBDA {
            for w in 0..words {
                let u = t_cols[i][w] ^ g1_cols[i][w] ^ r[w];
                u_payload.extend_from_slice(&u.to_le_bytes());
            }
        }
        chan.send_bytes(&u_payload);
        // Row keys: t_j (row j of the m×λ matrix).
        let rows = transpose_cols(&t_cols, m, threads);
        // Receive masked messages and unmask the chosen one.
        let payload = chan.recv_bytes();
        assert_eq!(payload.len(), 2 * m * msg_len, "iknp message frame");
        let sent = self.sent;
        let out = pool::parallel_gen(threads, m, |j| {
            let base = 2 * j * msg_len;
            let slot = if choices[j] { base + msg_len } else { base };
            let mut msg = payload[slot..slot + msg_len].to_vec();
            let mask = h_mask(sent + j as u64, rows[j], msg_len);
            xor_into(&mut msg, &mask);
            msg
        });
        self.sent += m as u64;
        out
    }
}

impl IknpSender {
    /// Cap the local fan-out at `threads` workers (wire bytes are
    /// unchanged for any value).
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = threads.max(1);
    }

    /// Send `pairs.len()` OTs; `pairs[j] = (x0, x1)`, both `msg_len` bytes.
    pub fn send(&mut self, chan: &mut Chan, pairs: &[(Vec<u8>, Vec<u8>)], msg_len: usize) {
        let m = pairs.len();
        let threads = self.threads;
        let words = (m + 63) / 64;
        // Receive correction matrix u (λ columns).
        let payload = chan.recv_bytes();
        assert_eq!(payload.len(), LAMBDA * words * 8, "iknp correction frame");
        let s = self.s;
        let q_cols = pool::parallel_map_mut(threads, &mut self.streams, |i, prg| {
            // q_i = G(k_{s_i}) ^ s_i·u_i
            let mut q = prg.u64s(words);
            if s[i] {
                for (w, qw) in q.iter_mut().enumerate() {
                    let off = (i * words + w) * 8;
                    let u = u64::from_le_bytes(payload[off..off + 8].try_into().unwrap());
                    *qw ^= u;
                }
            }
            q
        });
        let rows = transpose_cols(&q_cols, m, threads);
        // s as a row mask.
        let mut s_row: u128 = 0;
        for i in 0..LAMBDA {
            if self.s[i] {
                s_row |= 1u128 << i;
            }
        }
        // Mask both messages per OT (hash-heavy — fan out by OT index),
        // then ship them in index order.
        let sent = self.sent;
        let masked = pool::parallel_map(threads, pairs, |j, (x0, x1)| {
            assert_eq!(x0.len(), msg_len);
            assert_eq!(x1.len(), msg_len);
            let q = rows[j];
            let mut m0 = x0.clone();
            xor_into(&mut m0, &h_mask(sent + j as u64, q, msg_len));
            let mut m1 = x1.clone();
            xor_into(&mut m1, &h_mask(sent + j as u64, q ^ s_row, msg_len));
            (m0, m1)
        });
        let mut out = Vec::with_capacity(2 * m * msg_len);
        for (m0, m1) in &masked {
            out.extend_from_slice(m0);
            out.extend_from_slice(m1);
        }
        chan.send_bytes(&out);
        self.sent += m as u64;
    }
}

/// Transpose λ column bit-vectors (each `m` bits packed in u64 words)
/// into `m` row keys of 128 bits, sharding the rows across workers.
fn transpose_cols(cols: &[Vec<u64>], m: usize, threads: usize) -> Vec<u128> {
    let ranges = pool::chunk_ranges(m, threads.max(1));
    let parts = pool::parallel_map(threads, &ranges, |_, &(r0, r1)| {
        let mut rows = vec![0u128; r1 - r0];
        for (i, col) in cols.iter().enumerate() {
            for j in r0..r1 {
                if (col[j / 64] >> (j % 64)) & 1 == 1 {
                    rows[j - r0] |= 1u128 << i;
                }
            }
        }
        rows
    });
    parts.concat()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::run_two_party;

    #[test]
    fn extension_transfers_chosen_messages() {
        let m = 300;
        let choices: Vec<bool> = (0..m).map(|i| (i * 7 + 1) % 3 == 0).collect();
        let pairs: Vec<(Vec<u8>, Vec<u8>)> = (0..m)
            .map(|i| {
                (
                    vec![i as u8; 24],
                    vec![(i as u8).wrapping_add(1); 24],
                )
            })
            .collect();
        let ch = choices.clone();
        let ps = pairs.clone();
        let ((_, ms), (got, _)) = run_two_party(
            move |c| {
                let mut prg = Prg::new(201);
                let mut snd = setup_sender(c, &mut prg);
                snd.send(c, &ps, 24);
            },
            move |c| {
                let mut prg = Prg::new(202);
                let mut rcv = setup_receiver(c, &mut prg);
                rcv.recv(c, &ch, 24)
            },
        );
        for j in 0..m {
            let want = if choices[j] { &pairs[j].1 } else { &pairs[j].0 };
            assert_eq!(&got[j], want, "ot {j}");
        }
        // The extension phase must be cheap: no group elements beyond the
        // 128 base OTs (sanity: < 100 KB total for 300 OTs of 24B).
        assert!(ms.total().bytes_sent < 100_000);
    }

    #[test]
    fn two_batches_reuse_one_setup() {
        let ((_, _), (got, _)) = run_two_party(
            |c| {
                let mut prg = Prg::new(203);
                let mut snd = setup_sender(c, &mut prg);
                snd.send(c, &[(vec![1], vec![2])], 1);
                snd.send(c, &[(vec![3], vec![4])], 1);
            },
            |c| {
                let mut prg = Prg::new(204);
                let mut rcv = setup_receiver(c, &mut prg);
                let a = rcv.recv(c, &[true], 1);
                let b = rcv.recv(c, &[false], 1);
                (a, b)
            },
        );
        assert_eq!(got.0[0], vec![2]);
        assert_eq!(got.1[0], vec![3]);
    }

    #[test]
    fn fanned_out_extension_is_byte_identical() {
        // The same transfer with 4-worker endpoints must produce the
        // same chosen messages AND the same wire traffic as the
        // sequential run above — the tentpole's byte-determinism claim.
        let m = 150;
        let choices: Vec<bool> = (0..m).map(|i| i % 5 == 2).collect();
        let pairs: Vec<(Vec<u8>, Vec<u8>)> =
            (0..m).map(|i| (vec![i as u8; 9], vec![!(i as u8); 9])).collect();
        let mut results = Vec::new();
        for threads in [1usize, 4] {
            let ch = choices.clone();
            let ps = pairs.clone();
            let ((_, ms), (got, mr)) = run_two_party(
                move |c| {
                    let mut prg = Prg::new(205);
                    let mut snd = setup_sender(c, &mut prg);
                    snd.set_threads(threads);
                    snd.send(c, &ps, 9);
                },
                move |c| {
                    let mut prg = Prg::new(206);
                    let mut rcv = setup_receiver(c, &mut prg);
                    rcv.set_threads(threads);
                    rcv.recv(c, &ch, 9)
                },
            );
            results.push((got, ms.total().bytes_sent, mr.total().bytes_sent));
        }
        assert_eq!(results[0].0, results[1].0, "chosen messages must match");
        assert_eq!(results[0].1, results[1].1, "sender bytes must match");
        assert_eq!(results[0].2, results[1].2, "receiver bytes must match");
        for j in 0..m {
            let want = if choices[j] { &pairs[j].1 } else { &pairs[j].0 };
            assert_eq!(&results[1].0[j], want, "ot {j}");
        }
    }
}
