//! IKNP oblivious-transfer extension (Ishai-Kilian-Nissim-Petrank 2003).
//!
//! Stretches λ = 128 base OTs into millions of fast OTs using only a
//! block cipher and XOR — the workhorse behind OT-based triple
//! generation [17 in the paper]. Roles are reversed in the base phase:
//! the extension *sender* plays base-OT *receiver* with a random choice
//! vector `s`, the extension *receiver* plays base-OT sender with random
//! seed pairs.
//!
//! Per batch of m OTs with L-byte messages: the receiver transmits a
//! m×128-bit correction matrix; the sender transmits 2·m·L bytes of
//! masked messages.
//!
//! ## Fan-out
//!
//! The per-OT work — column-stream PRG expansion, the bit-matrix
//! transposition, and above all the correlation-robust hash per row key
//! — is pure local compute indexed by OT position, so both endpoints
//! shard it across [`IknpSender::set_threads`] /
//! [`IknpReceiver::set_threads`] workers via [`crate::runtime::pool`].
//! The frames on the wire are assembled in index order and are
//! **byte-identical** for any thread count; only wall-clock changes.

use super::baseot::{base_ot_recv, base_ot_send, OtGroup};
use crate::net::Chan;
use crate::runtime::pool;
use crate::runtime::simd;
use crate::util::hash::hash256_many;
use crate::util::prng::Prg;

/// Security parameter: number of base OTs / matrix width.
pub const LAMBDA: usize = 128;

/// Sender endpoint of the OT extension.
pub struct IknpSender {
    /// s: the random choice vector used in the base phase.
    s: [bool; LAMBDA],
    /// PRGs seeded by the chosen base-OT keys (column streams).
    streams: Vec<Prg>,
    /// OT counter for domain separation.
    sent: u64,
    /// Worker threads for the per-OT hashing/transposition fan-out.
    threads: usize,
}

/// Receiver endpoint of the OT extension.
pub struct IknpReceiver {
    /// PRG pairs from the base phase (both seeds known to receiver).
    streams0: Vec<Prg>,
    streams1: Vec<Prg>,
    sent: u64,
    /// Worker threads for the per-OT hashing/transposition fan-out.
    threads: usize,
}

/// Correlation-robust hash batch: expand 128-bit row keys into L-byte
/// masks, one per `(OT index, row key)` item.
///
/// Every hash input is the same fixed 24-byte shape (8-byte index ‖
/// 16-byte key), so the whole batch runs through the lockstep
/// [`hash256_many`] — [`simd::global_lanes`] digests per Speck sweep.
/// Only each digest's first 16 bytes seed the mask PRG — the second
/// hash lane is deliberately paid for anyway so the hash keeps the
/// drop-in SHA-256 shape (swap `util::hash` for hardware SHA-256 in
/// production without touching this call site).
fn h_masks(items: &[(u64, u128)], len: usize) -> Vec<Vec<u8>> {
    let inputs: Vec<[u8; 24]> = items
        .iter()
        .map(|&(index, q)| {
            let mut b = [0u8; 24];
            b[..8].copy_from_slice(&index.to_le_bytes());
            b[8..].copy_from_slice(&q.to_le_bytes());
            b
        })
        .collect();
    let refs: Vec<&[u8]> = inputs.iter().map(|b| b.as_slice()).collect();
    hash256_many(&refs)
        .into_iter()
        .map(|d| {
            let mut seed = [0u8; 16];
            seed.copy_from_slice(&d[..16]);
            let mut prg = Prg::from_seed(seed);
            let mut out = vec![0u8; len];
            prg.fill_bytes(&mut out);
            out
        })
        .collect()
}

fn xor_into(dst: &mut [u8], src: &[u8]) {
    for (d, s) in dst.iter_mut().zip(src) {
        *d ^= *s;
    }
}

/// Set up the sender endpoint (runs λ base OTs as base-receiver).
pub fn setup_sender(chan: &mut Chan, prg: &mut Prg) -> IknpSender {
    let group = OtGroup::rfc3526();
    let mut s = [false; LAMBDA];
    for b in s.iter_mut() {
        *b = prg.next_u64() & 1 == 1;
    }
    let keys = base_ot_recv(chan, &group, &s, prg);
    let streams = keys.into_iter().map(Prg::from_seed).collect();
    IknpSender { s, streams, sent: 0, threads: 1 }
}

/// Set up the receiver endpoint (runs λ base OTs as base-sender).
pub fn setup_receiver(chan: &mut Chan, prg: &mut Prg) -> IknpReceiver {
    let group = OtGroup::rfc3526();
    let keys = base_ot_send(chan, &group, LAMBDA, prg);
    let streams0 = keys.iter().map(|(k0, _)| Prg::from_seed(*k0)).collect();
    let streams1 = keys.iter().map(|(_, k1)| Prg::from_seed(*k1)).collect();
    IknpReceiver { streams0, streams1, sent: 0, threads: 1 }
}

impl IknpReceiver {
    /// Cap the local fan-out at `threads` workers (wire bytes are
    /// unchanged for any value).
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = threads.max(1);
    }

    /// Receive `choices.len()` OTs of `msg_len`-byte messages; returns
    /// the chosen message per OT.
    pub fn recv(&mut self, chan: &mut Chan, choices: &[bool], msg_len: usize) -> Vec<Vec<u8>> {
        let m = choices.len();
        let threads = self.threads;
        let words = (m + 63) / 64;
        // Choice bits packed.
        let mut r = vec![0u64; words];
        for (j, &c) in choices.iter().enumerate() {
            if c {
                r[j / 64] |= 1 << (j % 64);
            }
        }
        // Column streams: t_i = G(k0_i), u_i = t_i ^ G(k1_i) ^ r. Each
        // column's PRG advances exactly as it would sequentially (the
        // pool hands every worker a disjoint column range).
        let t_cols = pool::parallel_map_mut(threads, &mut self.streams0, |_, p| p.u64s(words));
        let g1_cols = pool::parallel_map_mut(threads, &mut self.streams1, |_, p| p.u64s(words));
        let mut u_payload = Vec::with_capacity(LAMBDA * words * 8);
        for i in 0..LAMBDA {
            for w in 0..words {
                let u = t_cols[i][w] ^ g1_cols[i][w] ^ r[w];
                u_payload.extend_from_slice(&u.to_le_bytes());
            }
        }
        chan.send_bytes(&u_payload);
        // Row keys: t_j (row j of the m×λ matrix).
        let rows = transpose_cols(&t_cols, m, threads);
        // Receive masked messages and unmask the chosen one. Workers
        // take disjoint index ranges and hash their masks in lockstep
        // batches — output order and mask values are index-determined,
        // so both knobs (threads, lanes) leave every byte unchanged.
        let payload = chan.recv_bytes();
        assert_eq!(payload.len(), 2 * m * msg_len, "iknp message frame");
        let sent = self.sent;
        let ranges = pool::chunk_ranges(m, threads.max(1));
        let parts = pool::parallel_map(threads, &ranges, |_, &(lo, hi)| {
            let items: Vec<(u64, u128)> =
                (lo..hi).map(|j| (sent + j as u64, rows[j])).collect();
            let masks = h_masks(&items, msg_len);
            let mut msgs = Vec::with_capacity(hi - lo);
            for (off, j) in (lo..hi).enumerate() {
                let base = 2 * j * msg_len;
                let slot = if choices[j] { base + msg_len } else { base };
                let mut msg = payload[slot..slot + msg_len].to_vec();
                xor_into(&mut msg, &masks[off]);
                msgs.push(msg);
            }
            msgs
        });
        self.sent += m as u64;
        parts.concat()
    }
}

impl IknpSender {
    /// Cap the local fan-out at `threads` workers (wire bytes are
    /// unchanged for any value).
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = threads.max(1);
    }

    /// Send `pairs.len()` OTs; `pairs[j] = (x0, x1)`, both `msg_len` bytes.
    pub fn send(&mut self, chan: &mut Chan, pairs: &[(Vec<u8>, Vec<u8>)], msg_len: usize) {
        let m = pairs.len();
        let threads = self.threads;
        let words = (m + 63) / 64;
        // Receive correction matrix u (λ columns).
        let payload = chan.recv_bytes();
        assert_eq!(payload.len(), LAMBDA * words * 8, "iknp correction frame");
        let s = self.s;
        let q_cols = pool::parallel_map_mut(threads, &mut self.streams, |i, prg| {
            // q_i = G(k_{s_i}) ^ s_i·u_i
            let mut q = prg.u64s(words);
            if s[i] {
                for (w, qw) in q.iter_mut().enumerate() {
                    let off = (i * words + w) * 8;
                    let u = u64::from_le_bytes(payload[off..off + 8].try_into().unwrap());
                    *qw ^= u;
                }
            }
            q
        });
        let rows = transpose_cols(&q_cols, m, threads);
        // s as a row mask.
        let mut s_row: u128 = 0;
        for i in 0..LAMBDA {
            if self.s[i] {
                s_row |= 1u128 << i;
            }
        }
        // Mask both messages per OT (hash-heavy — fan out by OT index
        // range, two lockstep-hashed masks per OT: `q_j` and `q_j ⊕ s`),
        // then ship them in index order.
        let sent = self.sent;
        let ranges = pool::chunk_ranges(m, threads.max(1));
        let masked = pool::parallel_map(threads, &ranges, |_, &(lo, hi)| {
            let mut items = Vec::with_capacity(2 * (hi - lo));
            for j in lo..hi {
                items.push((sent + j as u64, rows[j]));
                items.push((sent + j as u64, rows[j] ^ s_row));
            }
            let masks = h_masks(&items, msg_len);
            let mut part = Vec::with_capacity(2 * (hi - lo) * msg_len);
            for (off, j) in (lo..hi).enumerate() {
                let (x0, x1) = &pairs[j];
                assert_eq!(x0.len(), msg_len);
                assert_eq!(x1.len(), msg_len);
                let mut m0 = x0.clone();
                xor_into(&mut m0, &masks[2 * off]);
                let mut m1 = x1.clone();
                xor_into(&mut m1, &masks[2 * off + 1]);
                part.extend_from_slice(&m0);
                part.extend_from_slice(&m1);
            }
            part
        });
        chan.send_bytes(&masked.concat());
        self.sent += m as u64;
    }
}

/// Transpose λ = 128 column bit-vectors (each `m` bits packed LSB-first
/// in u64 words) into `m` row keys of 128 bits, via cache-blocked 64×64
/// bit-matrix transposes ([`simd::transpose64`]) sharded across workers
/// by 64-row block.
///
/// Column padding is explicit: each column must carry exactly
/// `⌈m/64⌉` words (asserted). When `m % 64 != 0` the tail bits of the
/// last word are **PRG stream garbage, not zero-fill** — the column
/// streams draw whole words — and the kernel must not let them leak:
/// each 64-row block is transposed in full, but only rows `< m` are
/// emitted, so the garbage lands exclusively in discarded output rows
/// (regression-tested at ragged sizes below).
fn transpose_cols(cols: &[Vec<u64>], m: usize, threads: usize) -> Vec<u128> {
    assert_eq!(cols.len(), LAMBDA, "transpose expects λ = {LAMBDA} columns");
    let words = m.div_ceil(64);
    for (i, col) in cols.iter().enumerate() {
        assert_eq!(
            col.len(),
            words,
            "column {i} has {} words; m = {m} needs exactly {words}",
            col.len()
        );
    }
    if m == 0 {
        return vec![];
    }
    // One 64-row block per column word; workers own disjoint block
    // ranges and emit rows in index order (thread-count independent).
    let ranges = pool::chunk_ranges(words, threads.max(1));
    let parts = pool::parallel_map(threads, &ranges, |_, &(b0, b1)| {
        let lo = b0 * 64;
        let hi = (b1 * 64).min(m);
        let mut rows = vec![0u128; hi - lo];
        for bi in b0..b1 {
            let r0 = bi * 64;
            let r1 = (r0 + 64).min(m);
            // Two 64-column groups make up the 128-bit row keys.
            for g in 0..2 {
                let mut blk = [0u64; 64];
                for i in 0..64 {
                    blk[i] = cols[g * 64 + i][bi];
                }
                simd::transpose64(&mut blk);
                // blk[j] now holds row (r0+j)'s bits for columns
                // 64g..64g+64; rows ≥ m (ragged tail) are dropped here.
                for j in r0..r1 {
                    rows[j - lo] |= (blk[j - r0] as u128) << (64 * g);
                }
            }
        }
        rows
    });
    parts.concat()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::run_two_party;

    #[test]
    fn extension_transfers_chosen_messages() {
        let m = 300;
        let choices: Vec<bool> = (0..m).map(|i| (i * 7 + 1) % 3 == 0).collect();
        let pairs: Vec<(Vec<u8>, Vec<u8>)> = (0..m)
            .map(|i| {
                (
                    vec![i as u8; 24],
                    vec![(i as u8).wrapping_add(1); 24],
                )
            })
            .collect();
        let ch = choices.clone();
        let ps = pairs.clone();
        let ((_, ms), (got, _)) = run_two_party(
            move |c| {
                let mut prg = Prg::new(201);
                let mut snd = setup_sender(c, &mut prg);
                snd.send(c, &ps, 24);
            },
            move |c| {
                let mut prg = Prg::new(202);
                let mut rcv = setup_receiver(c, &mut prg);
                rcv.recv(c, &ch, 24)
            },
        );
        for j in 0..m {
            let want = if choices[j] { &pairs[j].1 } else { &pairs[j].0 };
            assert_eq!(&got[j], want, "ot {j}");
        }
        // The extension phase must be cheap: no group elements beyond the
        // 128 base OTs (sanity: < 100 KB total for 300 OTs of 24B).
        assert!(ms.total().bytes_sent < 100_000);
    }

    #[test]
    fn two_batches_reuse_one_setup() {
        let ((_, _), (got, _)) = run_two_party(
            |c| {
                let mut prg = Prg::new(203);
                let mut snd = setup_sender(c, &mut prg);
                snd.send(c, &[(vec![1], vec![2])], 1);
                snd.send(c, &[(vec![3], vec![4])], 1);
            },
            |c| {
                let mut prg = Prg::new(204);
                let mut rcv = setup_receiver(c, &mut prg);
                let a = rcv.recv(c, &[true], 1);
                let b = rcv.recv(c, &[false], 1);
                (a, b)
            },
        );
        assert_eq!(got.0[0], vec![2]);
        assert_eq!(got.1[0], vec![3]);
    }

    /// Bit-probe reference for [`transpose_cols`] (the pre-blocking
    /// implementation): row j bit i = column i bit j, rows < m only.
    fn transpose_reference(cols: &[Vec<u64>], m: usize) -> Vec<u128> {
        let mut rows = vec![0u128; m];
        for (i, col) in cols.iter().enumerate() {
            for (j, row) in rows.iter_mut().enumerate() {
                if (col[j / 64] >> (j % 64)) & 1 == 1 {
                    *row |= 1u128 << i;
                }
            }
        }
        rows
    }

    #[test]
    fn blocked_transpose_matches_reference_at_ragged_sizes() {
        // m % 64 != 0 leaves tail bits in the last column word; the
        // column streams fill whole words, so those bits are PRG
        // garbage — NOT zeros — and must never reach an emitted row.
        let mut prg = Prg::new(0x7125);
        for m in [1usize, 63, 64, 65, 127, 128, 200, 300] {
            let words = m.div_ceil(64);
            let cols: Vec<Vec<u64>> = (0..LAMBDA).map(|_| prg.u64s(words)).collect();
            let want = transpose_reference(&cols, m);
            for threads in [1usize, 3, 8] {
                assert_eq!(
                    transpose_cols(&cols, m, threads),
                    want,
                    "m = {m}, threads = {threads}"
                );
            }
        }
        assert!(transpose_cols(&vec![vec![]; LAMBDA], 0, 4).is_empty());
    }

    #[test]
    #[should_panic(expected = "needs exactly")]
    fn transpose_rejects_underpadded_columns() {
        // 65 rows need 2 words per column; 1 word must be caught, not
        // silently read out of bounds or zero-filled.
        let cols: Vec<Vec<u64>> = vec![vec![0u64; 1]; LAMBDA];
        transpose_cols(&cols, 65, 1);
    }

    #[test]
    fn packed_masks_match_scalar_hash_reference() {
        use crate::runtime::simd::set_global_lanes;
        use crate::util::hash::Hash256;
        // The scalar reference: one streaming Hash256 + mask PRG per
        // item, exactly the pre-batching per-OT code.
        let reference = |index: u64, q: u128, len: usize| -> Vec<u8> {
            let mut h = Hash256::new();
            h.update(index.to_le_bytes());
            h.update(q.to_le_bytes());
            let d = h.finalize();
            let mut seed = [0u8; 16];
            seed.copy_from_slice(&d[..16]);
            let mut prg = Prg::from_seed(seed);
            let mut out = vec![0u8; len];
            prg.fill_bytes(&mut out);
            out
        };
        let items: Vec<(u64, u128)> =
            (0..13).map(|i| (1000 + i as u64, (i as u128) << 100 | 0xABC + i as u128)).collect();
        for len in [1usize, 9, 16, 24, 33] {
            let want: Vec<Vec<u8>> =
                items.iter().map(|&(i, q)| reference(i, q, len)).collect();
            for width in [1usize, 4, 8] {
                set_global_lanes(width);
                assert_eq!(h_masks(&items, len), want, "len={len} width={width}");
            }
            set_global_lanes(1);
        }
    }

    #[test]
    fn packed_lane_extension_is_byte_identical() {
        // The lanes analogue of the fan-out test: the same transfer at
        // lanes = 1 and lanes = 8 must produce the same chosen messages
        // AND the same wire traffic — the packed mask/transpose kernels
        // never touch a byte on the wire.
        use crate::runtime::simd::set_global_lanes;
        let m = 130; // ragged: not a multiple of 64 or 8
        let choices: Vec<bool> = (0..m).map(|i| i % 3 == 1).collect();
        let pairs: Vec<(Vec<u8>, Vec<u8>)> =
            (0..m).map(|i| (vec![i as u8; 24], vec![!(i as u8); 24])).collect();
        let mut results = Vec::new();
        for width in [1usize, 8] {
            set_global_lanes(width);
            let ch = choices.clone();
            let ps = pairs.clone();
            let ((_, ms), (got, mr)) = run_two_party(
                move |c| {
                    let mut prg = Prg::new(207);
                    let mut snd = setup_sender(c, &mut prg);
                    snd.send(c, &ps, 24);
                },
                move |c| {
                    let mut prg = Prg::new(208);
                    let mut rcv = setup_receiver(c, &mut prg);
                    rcv.recv(c, &ch, 24)
                },
            );
            set_global_lanes(1);
            results.push((got, ms.total().bytes_sent, mr.total().bytes_sent));
        }
        assert_eq!(results[0].0, results[1].0, "chosen messages must match");
        assert_eq!(results[0].1, results[1].1, "sender bytes must match");
        assert_eq!(results[0].2, results[1].2, "receiver bytes must match");
        for j in 0..m {
            let want = if choices[j] { &pairs[j].1 } else { &pairs[j].0 };
            assert_eq!(&results[1].0[j], want, "ot {j}");
        }
    }

    #[test]
    fn fanned_out_extension_is_byte_identical() {
        // The same transfer with 4-worker endpoints must produce the
        // same chosen messages AND the same wire traffic as the
        // sequential run above — the tentpole's byte-determinism claim.
        let m = 150;
        let choices: Vec<bool> = (0..m).map(|i| i % 5 == 2).collect();
        let pairs: Vec<(Vec<u8>, Vec<u8>)> =
            (0..m).map(|i| (vec![i as u8; 9], vec![!(i as u8); 9])).collect();
        let mut results = Vec::new();
        for threads in [1usize, 4] {
            let ch = choices.clone();
            let ps = pairs.clone();
            let ((_, ms), (got, mr)) = run_two_party(
                move |c| {
                    let mut prg = Prg::new(205);
                    let mut snd = setup_sender(c, &mut prg);
                    snd.set_threads(threads);
                    snd.send(c, &ps, 9);
                },
                move |c| {
                    let mut prg = Prg::new(206);
                    let mut rcv = setup_receiver(c, &mut prg);
                    rcv.set_threads(threads);
                    rcv.recv(c, &ch, 9)
                },
            );
            results.push((got, ms.total().bytes_sent, mr.total().bytes_sent));
        }
        assert_eq!(results[0].0, results[1].0, "chosen messages must match");
        assert_eq!(results[0].1, results[1].1, "sender bytes must match");
        assert_eq!(results[0].2, results[1].2, "receiver bytes must match");
        for j in 0..m {
            let want = if choices[j] { &pairs[j].1 } else { &pairs[j].0 };
            assert_eq!(&results[1].0[j], want, "ot {j}");
        }
    }
}
