//! OT-based Beaver triple generation (Gilboa products over IKNP).
//!
//! This is the cryptographic offline phase the paper prices in Tables
//! 1-2: triples are produced by two-party protocols only (no dealer).
//! A Gilboa product shares `x·y` where P_a holds `x` and P_b holds `y`:
//! for every bit `b` of the chooser's input, the sender offers
//! `(r_b, r_b + 2^b·x)` through OT; the chooser's picks telescope to
//! `Σ r_b + x·y`. Matrix triples batch whole rows/columns into each OT
//! message, which is why offline *communication* — not computation — is
//! the dominant cost (compare the paper's 131 GB offline for n = 10^5).

use super::iknp::{setup_receiver, setup_sender, IknpReceiver, IknpSender};
use crate::net::Chan;
use crate::ring::matrix::Mat;
use crate::ss::triples::{
    bit_words, last_word_mask, BitTriple, DaBits, Ledger, MatTriple, TripleSource, VecTriple,
};
use crate::util::prng::Prg;

/// Two-party OT-based triple generator; implements [`TripleSource`].
///
/// Owns a dedicated channel (offline traffic is metered separately from
/// the online phase). Both parties must issue identical request
/// sequences — true by construction since the online protocol is
/// symmetric.
pub struct OtTripleGen {
    chan: Chan,
    party: usize,
    prg: Prg,
    sender: IknpSender,
    receiver: IknpReceiver,
    ledger: Ledger,
}

impl OtTripleGen {
    /// Run the base-OT setup on `chan` (party index is taken from it).
    pub fn new(mut chan: Chan, seed: u128) -> OtTripleGen {
        let party = chan.party;
        let mut prg = Prg::new(seed ^ (party as u128 + 1) * 0x9E3779B97F4A7C15);
        chan.set_phase("offline.baseot");
        // Party 0: sender-setup then receiver-setup; party 1 mirrors.
        let (sender, receiver) = if party == 0 {
            let s = setup_sender(&mut chan, &mut prg);
            let r = setup_receiver(&mut chan, &mut prg);
            (s, r)
        } else {
            let r = setup_receiver(&mut chan, &mut prg);
            let s = setup_sender(&mut chan, &mut prg);
            (s, r)
        };
        chan.set_phase("offline.triples");
        OtTripleGen { chan, party, prg, sender, receiver, ledger: Ledger::default() }
    }

    /// Cap the local per-OT fan-out (hashing, transposition, column
    /// PRGs) at `threads` workers on both IKNP endpoints. Wire traffic
    /// and the generated triples are identical for any value — only
    /// generation wall-clock changes.
    pub fn set_threads(&mut self, threads: usize) {
        self.sender.set_threads(threads);
        self.receiver.set_threads(threads);
    }

    /// Bytes sent by this party's offline channel so far.
    pub fn bytes_sent(&self) -> u64 {
        self.chan.meter().total().bytes_sent
    }

    /// Consume, returning the offline channel's meter.
    pub fn into_meter(self) -> crate::net::Meter {
        self.chan.into_meter()
    }

    /// Gilboa cross product where **this party holds `xs`** (the choice
    /// side) and the peer holds a vector multiplicand per lane. Returns
    /// this party's share of `Σ_b 2^b·x_b ⊙ y`: concretely, lane-wise
    /// `x[i]·y[i]` shares (`vec_len` = 1) or `x[i] · y_vec` row shares.
    fn gilboa_choose(&mut self, xs: &[u64], vec_len: usize) -> Vec<u64> {
        let lanes = xs.len();
        // Choice bits: 64 per lane, little-endian bit order.
        let mut choices = Vec::with_capacity(lanes * 64);
        for &x in xs {
            for b in 0..64 {
                choices.push((x >> b) & 1 == 1);
            }
        }
        let msg_len = vec_len * 8;
        let got = self.receiver.recv(&mut self.chan, &choices, msg_len);
        // Accumulate Σ picks per lane (wrapping), giving our share.
        let mut out = vec![0u64; lanes * vec_len];
        for (ot, msg) in got.iter().enumerate() {
            let lane = ot / 64;
            for j in 0..vec_len {
                let v = u64::from_le_bytes(msg[j * 8..(j + 1) * 8].try_into().unwrap());
                let cell = &mut out[lane * vec_len + j];
                *cell = cell.wrapping_add(v);
            }
        }
        out
    }

    /// Gilboa cross product where **this party holds the multiplicand
    /// vectors `ys`** (one `vec_len`-length vector per lane, flattened).
    fn gilboa_offer(&mut self, ys: &[u64], lanes: usize, vec_len: usize) -> Vec<u64> {
        assert_eq!(ys.len(), lanes * vec_len);
        let msg_len = vec_len * 8;
        let mut pairs = Vec::with_capacity(lanes * 64);
        let mut share = vec![0u64; lanes * vec_len];
        for lane in 0..lanes {
            let y = &ys[lane * vec_len..(lane + 1) * vec_len];
            for b in 0..64 {
                let r: Vec<u64> = self.prg.u64s(vec_len);
                let mut m0 = Vec::with_capacity(msg_len);
                let mut m1 = Vec::with_capacity(msg_len);
                for j in 0..vec_len {
                    m0.extend_from_slice(&r[j].to_le_bytes());
                    m1.extend_from_slice(&r[j].wrapping_add(y[j] << b).to_le_bytes());
                    let cell = &mut share[lane * vec_len + j];
                    *cell = cell.wrapping_sub(r[j]);
                }
                pairs.push((m0, m1));
            }
        }
        self.sender.send(&mut self.chan, &pairs, msg_len);
        share
    }

    /// Boolean cross term: share of `a ⊙ b` where this party holds `a`
    /// (choice side), peer holds `b`. One OT per lane, 1-byte messages.
    fn bool_cross_choose(&mut self, a: &[u64], n: usize) -> Vec<u64> {
        let choices: Vec<bool> = (0..n).map(|i| (a[i / 64] >> (i % 64)) & 1 == 1).collect();
        let got = self.receiver.recv(&mut self.chan, &choices, 1);
        let mut out = vec![0u64; bit_words(n)];
        for (i, m) in got.iter().enumerate() {
            if m[0] & 1 == 1 {
                out[i / 64] |= 1 << (i % 64);
            }
        }
        out
    }

    fn bool_cross_offer(&mut self, b: &[u64], n: usize) -> Vec<u64> {
        let mut share = vec![0u64; bit_words(n)];
        let mut pairs = Vec::with_capacity(n);
        for i in 0..n {
            let r = (self.prg.next_u64() & 1) as u8;
            let bv = ((b[i / 64] >> (i % 64)) & 1) as u8;
            pairs.push((vec![r], vec![r ^ bv]));
            if r == 1 {
                share[i / 64] |= 1 << (i % 64);
            }
        }
        self.sender.send(&mut self.chan, &pairs, 1);
        share
    }
}

impl TripleSource for OtTripleGen {
    fn vec_triple(&mut self, n: usize) -> VecTriple {
        self.ledger.vec_triple_lanes += n as u64;
        let u: Vec<u64> = self.prg.u64s(n);
        let v: Vec<u64> = self.prg.u64s(n);
        // z = u·v needs cross terms u0·v1 and u1·v0.
        // Direction 1: party0 chooses with u0, party1 offers v1.
        let c1 = if self.party == 0 {
            self.gilboa_choose(&u, 1)
        } else {
            self.gilboa_offer(&v, n, 1)
        };
        // Direction 2: party1 chooses with u1, party0 offers v0.
        let c2 = if self.party == 1 {
            self.gilboa_choose(&u, 1)
        } else {
            self.gilboa_offer(&v, n, 1)
        };
        let z: Vec<u64> = (0..n)
            .map(|i| u[i].wrapping_mul(v[i]).wrapping_add(c1[i]).wrapping_add(c2[i]))
            .collect();
        VecTriple { u, v, z }
    }

    fn mat_triple(&mut self, m: usize, k: usize, n: usize) -> MatTriple {
        self.ledger.mat_triples += 1;
        self.ledger.mat_triple_elems += (m * k + k * n + m * n) as u64;
        let u = Mat::random(m, k, &mut self.prg);
        let v = Mat::random(k, n, &mut self.prg);
        // Z = U·V = U0V0 + U0V1 + U1V0 + U1V1; local term plus two cross
        // outer-product sums over the inner dimension.
        let mut z = u.matmul(&v);
        // Cross A: party0's U picks, party1's V offers (per inner index t:
        // lanes = m entries of U[:,t], each multiplying row V[t,:]).
        for t in 0..k {
            let share = if self.party == 0 {
                let col: Vec<u64> = (0..m).map(|i| u.at(i, t)).collect();
                self.gilboa_choose(&col, n)
            } else {
                let row: Vec<u64> = v.row(t).to_vec();
                // Same row offered against each of the m chooser lanes.
                let ys: Vec<u64> = (0..m).flat_map(|_| row.clone()).collect();
                self.gilboa_offer(&ys, m, n)
            };
            for i in 0..m {
                for j in 0..n {
                    let cell = &mut z.data[i * n + j];
                    *cell = cell.wrapping_add(share[i * n + j]);
                }
            }
        }
        // Cross B: roles swapped.
        for t in 0..k {
            let share = if self.party == 1 {
                let col: Vec<u64> = (0..m).map(|i| u.at(i, t)).collect();
                self.gilboa_choose(&col, n)
            } else {
                let row: Vec<u64> = v.row(t).to_vec();
                let ys: Vec<u64> = (0..m).flat_map(|_| row.clone()).collect();
                self.gilboa_offer(&ys, m, n)
            };
            for i in 0..m {
                for j in 0..n {
                    let cell = &mut z.data[i * n + j];
                    *cell = cell.wrapping_add(share[i * n + j]);
                }
            }
        }
        MatTriple { u, v, z }
    }

    fn bit_triple(&mut self, n: usize) -> BitTriple {
        self.ledger.bit_triple_lanes += n as u64;
        let w = bit_words(n);
        let a: Vec<u64> = self.prg.u64s(w);
        let b: Vec<u64> = self.prg.u64s(w);
        // c = a&b ⊕ cross(a0,b1) ⊕ cross(a1,b0)
        let c1 = if self.party == 0 {
            self.bool_cross_choose(&a, n)
        } else {
            self.bool_cross_offer(&b, n)
        };
        let c2 = if self.party == 1 {
            self.bool_cross_choose(&a, n)
        } else {
            self.bool_cross_offer(&b, n)
        };
        let c: Vec<u64> = (0..w).map(|i| (a[i] & b[i]) ^ c1[i] ^ c2[i]).collect();
        BitTriple { a, b, c, n }
    }

    fn dabits(&mut self, n: usize) -> DaBits {
        self.ledger.dabit_lanes += n as u64;
        let w = bit_words(n);
        // Each party privately samples its XOR share r_p; the additive
        // share is r_p − 2·⟨r₀·r₁⟩ where the cross term comes from one
        // Gilboa product (party 0 chooses, party 1 offers).
        let mut bool_words = self.prg.u64s(w);
        if let Some(last) = bool_words.last_mut() {
            *last &= last_word_mask(n);
        }
        let my_bits: Vec<u64> =
            (0..n).map(|i| (bool_words[i / 64] >> (i % 64)) & 1).collect();
        let cross = if self.party == 0 {
            self.gilboa_choose(&my_bits, 1)
        } else {
            self.gilboa_offer(&my_bits, n, 1)
        };
        let arith: Vec<u64> =
            (0..n).map(|i| my_bits[i].wrapping_sub(cross[i].wrapping_mul(2))).collect();
        DaBits { n, bool_words, arith }
    }

    fn ledger(&self) -> Ledger {
        self.ledger
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::duplex_pair;
    use std::thread;

    fn run_gen<R0: Send + 'static, R1: Send + 'static>(
        f0: impl FnOnce(&mut OtTripleGen) -> R0 + Send + 'static,
        f1: impl FnOnce(&mut OtTripleGen) -> R1 + Send + 'static,
    ) -> (R0, R1) {
        let (c0, c1) = duplex_pair();
        let h0 = thread::spawn(move || {
            let mut g = OtTripleGen::new(c0, 777);
            f0(&mut g)
        });
        let h1 = thread::spawn(move || {
            let mut g = OtTripleGen::new(c1, 777);
            f1(&mut g)
        });
        (h0.join().unwrap(), h1.join().unwrap())
    }

    #[test]
    fn ot_vec_triples_are_valid() {
        let (t0, t1) = run_gen(|g| g.vec_triple(20), |g| g.vec_triple(20));
        for i in 0..20 {
            let u = t0.u[i].wrapping_add(t1.u[i]);
            let v = t0.v[i].wrapping_add(t1.v[i]);
            let z = t0.z[i].wrapping_add(t1.z[i]);
            assert_eq!(u.wrapping_mul(v), z, "lane {i}");
        }
    }

    #[test]
    fn ot_mat_triples_are_valid() {
        let (t0, t1) = run_gen(|g| g.mat_triple(3, 2, 4), |g| g.mat_triple(3, 2, 4));
        let u = t0.u.add(&t1.u);
        let v = t0.v.add(&t1.v);
        let z = t0.z.add(&t1.z);
        assert_eq!(u.matmul(&v), z);
    }

    #[test]
    fn ot_dabits_are_valid() {
        let (a, b) = run_gen(|g| g.dabits(70), |g| g.dabits(70));
        for i in 0..70 {
            let bool_bit = ((a.bool_words[i / 64] ^ b.bool_words[i / 64]) >> (i % 64)) & 1;
            let arith_bit = a.arith[i].wrapping_add(b.arith[i]);
            assert_eq!(bool_bit, arith_bit, "lane {i}");
            assert!(arith_bit <= 1, "lane {i}: not a bit");
        }
    }

    #[test]
    fn fanned_out_generation_is_bit_identical() {
        // A 4-worker generator must produce exactly the sequential
        // generator's triples (same seeds → same OT transcript → same
        // shares); the fan-out only reschedules local hashing.
        let run = |threads: usize| {
            let (c0, c1) = duplex_pair();
            let h0 = thread::spawn(move || {
                let mut g = OtTripleGen::new(c0, 555);
                g.set_threads(threads);
                (g.mat_triple(3, 2, 4), g.vec_triple(10))
            });
            let h1 = thread::spawn(move || {
                let mut g = OtTripleGen::new(c1, 555);
                g.set_threads(threads);
                (g.mat_triple(3, 2, 4), g.vec_triple(10))
            });
            (h0.join().unwrap(), h1.join().unwrap())
        };
        let ((a0m, a0v), (a1m, a1v)) = run(1);
        let ((b0m, b0v), (b1m, b1v)) = run(4);
        assert_eq!(a0m.z, b0m.z);
        assert_eq!(a1m.z, b1m.z);
        assert_eq!(a0v.z, b0v.z);
        assert_eq!(a1v.z, b1v.z);
        // And the parallel run's shares still reconstruct.
        let u = b0m.u.add(&b1m.u);
        let v = b0m.v.add(&b1m.v);
        let z = b0m.z.add(&b1m.z);
        assert_eq!(u.matmul(&v), z);
    }

    #[test]
    fn ot_bit_triples_are_valid() {
        let (t0, t1) = run_gen(|g| g.bit_triple(100), |g| g.bit_triple(100));
        for i in 0..t0.a.len() {
            let a = t0.a[i] ^ t1.a[i];
            let b = t0.b[i] ^ t1.b[i];
            let c = t0.c[i] ^ t1.c[i];
            let mask = if i == t0.a.len() - 1 {
                crate::ss::triples::last_word_mask(100)
            } else {
                u64::MAX
            };
            assert_eq!((a & b) & mask, c & mask, "word {i}");
        }
    }
}
