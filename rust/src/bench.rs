//! Minimal bench harness (criterion is unavailable offline): warmup +
//! repeated timing with mean/stddev, and aligned table printing for the
//! paper's tables and figure series.

use crate::util::stats;
use std::time::Instant;

/// Time `f` over `reps` repetitions after `warmup` runs; returns seconds per rep.
pub fn time_reps(warmup: usize, reps: usize, mut f: impl FnMut()) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect()
}

/// A printable results table.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn print(&self) {
        println!("\n== {} ==", self.title);
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:>w$}  ", c, w = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        for row in &self.rows {
            line(row);
        }
    }
}

/// Format seconds adaptively.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else if s < 120.0 {
        format!("{:.2}s", s)
    } else {
        format!("{:.2}min", s / 60.0)
    }
}

/// Format bytes adaptively.
pub fn fmt_bytes(b: u64) -> String {
    let bf = b as f64;
    if bf < 1e3 {
        format!("{b}B")
    } else if bf < 1e6 {
        format!("{:.1}KB", bf / 1e3)
    } else if bf < 1e9 {
        format!("{:.1}MB", bf / 1e6)
    } else {
        format!("{:.2}GB", bf / 1e9)
    }
}

/// Summarize reps as "mean ± std".
pub fn summarize(xs: &[f64]) -> String {
    format!("{} ± {}", fmt_secs(stats::mean(xs)), fmt_secs(stats::stddev(xs)))
}

// ---- Goldenable communication counts -------------------------------------
//
// The bench-smoke CI job gates on *exact* flight/byte counts: wall-clock
// is hardware-dependent and stays informational, but every byte and
// every flight is deterministic, so drift there is a real protocol
// change. These helpers compute the counts the goldens in
// `rust/tests/goldens/` pin, shared by the table benches (JSON emission)
// and the `bench_goldens` regression test.

use crate::data::blobs::BlobSpec;
use crate::data::fraud_gen;
use crate::kmeans::config::{Partition, SecureKmeansConfig};
use crate::kmeans::secure;
use crate::net::mux::MUX_LINK_PHASE;
use crate::net::Security;
use crate::offline::bank::BankConfig;
use crate::offline::pricing;
use crate::serve::driver::{serve_stream, train_model, ServeConfig};
use crate::serve::gateway::{gateway_stream, GatewayConfig};

/// Exact communication counts of one secure training run.
pub struct RunCounts {
    /// Samples.
    pub n: usize,
    /// Features.
    pub d: usize,
    /// Clusters.
    pub k: usize,
    /// Lloyd iterations.
    pub iters: usize,
    /// Online bytes, both parties summed.
    pub online_bytes: u64,
    /// Online flights (party 0).
    pub online_rounds: u64,
    /// Per-step online bytes (s1, s2, s3), both parties.
    pub step_bytes: [u64; 3],
    /// Per-step online flights (party 0).
    pub step_rounds: [u64; 3],
    /// Offline bytes, OT-priced from the recorded demand.
    pub offline_bytes: u64,
    /// Matrix triples demanded.
    pub mat_triples: u64,
    /// Boolean AND-triple lanes consumed.
    pub bit_triple_lanes: u64,
    /// daBit lanes consumed.
    pub dabit_lanes: u64,
}

/// Run the tables' canonical configuration (vertical split at d/2) and
/// extract its exact counts.
pub fn train_counts(n: usize, d: usize, k: usize, iters: usize) -> RunCounts {
    let ds = BlobSpec::new(n, d, k).generate(1);
    let cfg = SecureKmeansConfig {
        k,
        iters,
        partition: Partition::Vertical { d_a: (d / 2).max(1) },
        ..Default::default()
    };
    let out = secure::run(&ds, &cfg).expect("train run");
    let both = |label: &str| out.meter_a.get(label).bytes_sent + out.meter_b.get(label).bytes_sent;
    RunCounts {
        n,
        d,
        k,
        iters,
        online_bytes: out.meter_a.total_prefix("online.").bytes_sent
            + out.meter_b.total_prefix("online.").bytes_sent,
        online_rounds: out.meter_a.total_prefix("online.").rounds,
        step_bytes: [both("online.s1"), both("online.s2"), both("online.s3")],
        step_rounds: [
            out.meter_a.get("online.s1").rounds,
            out.meter_a.get("online.s2").rounds,
            out.meter_a.get("online.s3").rounds,
        ],
        offline_bytes: pricing::offline_bytes(&out.demand),
        mat_triples: out.ledger.mat_triples,
        bit_triple_lanes: out.ledger.bit_triple_lanes,
        dabit_lanes: out.ledger.dabit_lanes,
    }
}

/// The golden-file rendering of [`RunCounts`] (`key = value` lines).
pub fn train_golden_lines(c: &RunCounts) -> String {
    format!(
        "config = n{} d{} k{} t{}\n\
         online_bytes = {}\n\
         online_rounds = {}\n\
         s1_bytes = {}\ns2_bytes = {}\ns3_bytes = {}\n\
         s1_rounds = {}\ns2_rounds = {}\ns3_rounds = {}\n\
         offline_bytes = {}\n\
         mat_triples = {}\nbit_triple_lanes = {}\ndabit_lanes = {}\n",
        c.n,
        c.d,
        c.k,
        c.iters,
        c.online_bytes,
        c.online_rounds,
        c.step_bytes[0],
        c.step_bytes[1],
        c.step_bytes[2],
        c.step_rounds[0],
        c.step_rounds[1],
        c.step_rounds[2],
        c.offline_bytes,
        c.mat_triples,
        c.bit_triple_lanes,
        c.dabit_lanes,
    )
}

/// Exact malicious-tier surcharge of one secure training run over its
/// semi-honest twin. Every phase except `mac.barrier` (which only the
/// malicious tier has) and `reveal` (commit-reveal adds a 32-byte
/// digest per opening) is transcript-byte-identical across the two
/// tiers — regression-tested in `rust/tests/tamper.rs` — so these
/// numbers *are* the whole cost of authentication.
pub struct MaliciousCounts {
    /// Samples.
    pub n: usize,
    /// Features.
    pub d: usize,
    /// Clusters.
    pub k: usize,
    /// Lloyd iterations.
    pub iters: usize,
    /// Online bytes under the malicious tier (`online.` prefix, both
    /// parties summed) — equals the semi-honest figure by construction.
    pub online_bytes: u64,
    /// `mac.barrier` bytes, both parties summed (96 per party per
    /// barrier: 32B commit + 56B reveal + 8B verdict).
    pub mac_barrier_bytes: u64,
    /// `mac.barrier` flights (party 0; 3 per barrier, one barrier per
    /// Lloyd iteration plus the `train.done` barrier).
    pub mac_barrier_rounds: u64,
    /// Commit-reveal surcharge on the `reveal` phase, both parties
    /// summed, relative to the semi-honest reveal (32 bytes per
    /// opened matrix per party).
    pub reveal_extra_bytes: u64,
    /// Extra reveal flights (party 0; one commit flight per opening).
    pub reveal_extra_rounds: u64,
}

impl MaliciousCounts {
    /// Total extra bytes the tier costs, both parties summed.
    pub fn extra_bytes(&self) -> u64 {
        self.mac_barrier_bytes + self.reveal_extra_bytes
    }

    /// Total extra flights (party 0).
    pub fn extra_rounds(&self) -> u64 {
        self.mac_barrier_rounds + self.reveal_extra_rounds
    }
}

/// Run the tables' canonical configuration under both security tiers
/// and extract the exact malicious surcharge.
pub fn train_malicious_counts(n: usize, d: usize, k: usize, iters: usize) -> MaliciousCounts {
    let ds = BlobSpec::new(n, d, k).generate(1);
    let cfg = |security| SecureKmeansConfig {
        k,
        iters,
        partition: Partition::Vertical { d_a: (d / 2).max(1) },
        security,
        ..Default::default()
    };
    let sh = secure::run(&ds, &cfg(Security::SemiHonest)).expect("semi-honest run");
    let mal = secure::run(&ds, &cfg(Security::Malicious)).expect("malicious run");
    assert_eq!(
        sh.assignments, mal.assignments,
        "the tiers must agree on the clustering (same transcripts, extra checks)"
    );
    let both = |out: &secure::SecureKmeansOutput, label: &str| {
        out.meter_a.get(label).bytes_sent + out.meter_b.get(label).bytes_sent
    };
    MaliciousCounts {
        n,
        d,
        k,
        iters,
        online_bytes: mal.meter_a.total_prefix("online.").bytes_sent
            + mal.meter_b.total_prefix("online.").bytes_sent,
        mac_barrier_bytes: both(&mal, "mac.barrier"),
        mac_barrier_rounds: mal.meter_a.get("mac.barrier").rounds,
        reveal_extra_bytes: both(&mal, "reveal") - both(&sh, "reveal"),
        reveal_extra_rounds: mal.meter_a.get("reveal").rounds - sh.meter_a.get("reveal").rounds,
    }
}

/// The golden-file rendering of [`MaliciousCounts`].
pub fn malicious_golden_lines(c: &MaliciousCounts) -> String {
    format!(
        "config = n{} d{} k{} t{} malicious\n\
         online_bytes = {}\n\
         mac_barrier_bytes = {}\n\
         mac_barrier_rounds = {}\n\
         reveal_extra_bytes = {}\n\
         reveal_extra_rounds = {}\n",
        c.n,
        c.d,
        c.k,
        c.iters,
        c.online_bytes,
        c.mac_barrier_bytes,
        c.mac_barrier_rounds,
        c.reveal_extra_bytes,
        c.reveal_extra_rounds,
    )
}

/// Exact communication counts of one serving run.
pub struct ServeCounts {
    /// Clusters of the served model.
    pub k: usize,
    /// Transactions per micro-batch.
    pub batch_rows: usize,
    /// Micro-batches scored.
    pub batches: usize,
    /// Online flights per batch (uniform, == `score_rounds(k)`).
    pub rounds_per_batch: u64,
    /// Steady-state online bytes per batch (party 0).
    pub bytes_per_batch: u64,
    /// Warmup (norm-row) bytes (party 0).
    pub warmup_bytes: u64,
    /// Bank ledger: prefabricated, replenished, consumed, remaining.
    pub bank_ledger: [usize; 4],
    /// Bank misses (must stay 0).
    pub bank_misses: u64,
    /// Matrix-triple bytes of one prefabricated bank batch.
    pub mat_triple_bytes_per_batch: u64,
}

/// Train a small fraud model and score a stream with a replenished
/// bank, extracting the exact serving counts.
pub fn serve_counts(
    n_train: usize,
    k: usize,
    iters: usize,
    batch_rows: usize,
    batches: usize,
) -> ServeCounts {
    let f = fraud_gen::generate(n_train, 0.05, 77);
    let cfg = SecureKmeansConfig {
        k,
        iters,
        partition: Partition::Vertical { d_a: f.d_payment },
        ..Default::default()
    };
    let (_, models) = train_model(&f.data, &cfg, 0.05).expect("train model");
    let stream = fraud_gen::generate(batches * batch_rows, 0.05, 4242);
    let scfg = ServeConfig {
        batch_rows,
        batches,
        bank: BankConfig { prefab_batches: 2, low_water: 1, refill_batches: 2 },
        ..Default::default()
    };
    let out = serve_stream(models, &stream.data, &scfg).expect("serve stream");
    let steady = out.batch_stats[out.batch_stats.len().min(2) - 1].online;
    ServeCounts {
        k,
        batch_rows,
        batches,
        rounds_per_batch: steady.rounds,
        bytes_per_batch: steady.bytes_sent,
        warmup_bytes: out.warmup_stats.bytes_sent,
        bank_ledger: [
            out.bank_prefabricated,
            out.bank_replenished,
            out.bank_consumed,
            out.bank_remaining,
        ],
        bank_misses: out.bank_misses,
        mat_triple_bytes_per_batch: out.per_batch_mat_triple_bytes,
    }
}

/// The golden-file rendering of [`ServeCounts`].
pub fn serve_golden_lines(c: &ServeCounts) -> String {
    format!(
        "config = k{} b{}x{}\n\
         rounds_per_batch = {}\n\
         bytes_per_batch = {}\n\
         warmup_bytes = {}\n\
         bank_ledger = {}+{}-{}={}\n\
         bank_misses = {}\n\
         mat_triple_bytes_per_batch = {}\n",
        c.k,
        c.batches,
        c.batch_rows,
        c.rounds_per_batch,
        c.bytes_per_batch,
        c.warmup_bytes,
        c.bank_ledger[0],
        c.bank_ledger[1],
        c.bank_ledger[2],
        c.bank_ledger[3],
        c.bank_misses,
        c.mat_triple_bytes_per_batch,
    )
}

/// Exact communication counts of one gateway run — deterministic
/// quantities only. Scheduling-dependent throughput facts (`stalls`,
/// `replenished`, link flights) are deliberately excluded so the golden
/// is stable across worker counts and machines.
pub struct GatewayCounts {
    /// Clusters of the served model.
    pub k: usize,
    /// Concurrent sessions multiplexed over the link.
    pub sessions: usize,
    /// Transactions per micro-batch.
    pub batch_rows: usize,
    /// Micro-batches per session.
    pub batches: usize,
    /// Session 1's online bytes (party 0) — every tag scores the same
    /// shape, so this is the per-session cost at any concurrency level.
    pub session_bytes: u64,
    /// Session 1's online flights (party 0).
    pub session_rounds: u64,
    /// Link-level `gateway.mux` bytes (party 0): the exact sum of the
    /// per-session meters, tags included.
    pub link_bytes: u64,
    /// Link-level `gateway.mux` messages (party 0).
    pub link_msgs: u64,
    /// Kits checked out (== sessions · batches).
    pub consumed: u64,
    /// Bank misses (must stay 0).
    pub misses: u64,
}

/// Train a small fraud model and run a gateway session sweep over the
/// duplex link, extracting the exact deterministic counts.
pub fn gateway_counts(
    n_train: usize,
    k: usize,
    iters: usize,
    sessions: usize,
    batch_rows: usize,
    batches: usize,
) -> GatewayCounts {
    let f = fraud_gen::generate(n_train, 0.05, 77);
    let cfg = SecureKmeansConfig {
        k,
        iters,
        partition: Partition::Vertical { d_a: f.d_payment },
        ..Default::default()
    };
    let (_, models) = train_model(&f.data, &cfg, 0.05).expect("train model");
    let stream = fraud_gen::generate(sessions * batches * batch_rows, 0.05, 4242);
    let gcfg = GatewayConfig {
        sessions,
        batch_rows,
        batches,
        bank: BankConfig { prefab_batches: 1, low_water: 1, refill_batches: 1 },
        ..Default::default()
    };
    let out = gateway_stream([models[0].clone(), models[1].clone()], &stream.data, &gcfg)
        .expect("gateway stream");
    let s1 = out
        .a
        .sessions
        .iter()
        .find(|(tag, _)| *tag == 1)
        .and_then(|(_, r)| r.as_ref().ok())
        .expect("session 1 succeeded");
    let link = out.meter_a.get(MUX_LINK_PHASE);
    GatewayCounts {
        k,
        sessions,
        batch_rows,
        batches,
        session_bytes: s1.online.bytes_sent,
        session_rounds: s1.online.rounds,
        link_bytes: link.bytes_sent,
        link_msgs: link.msgs_sent,
        consumed: out.a.ledger.consumed,
        misses: out.a.misses(),
    }
}

/// The golden-file rendering of [`GatewayCounts`].
pub fn gateway_golden_lines(c: &GatewayCounts) -> String {
    format!(
        "config = k{} s{} b{}x{}\n\
         session_bytes = {}\n\
         session_rounds = {}\n\
         link_bytes = {}\n\
         link_msgs = {}\n\
         consumed = {}\n\
         misses = {}\n",
        c.k,
        c.sessions,
        c.batches,
        c.batch_rows,
        c.session_bytes,
        c.session_rounds,
        c.link_bytes,
        c.link_msgs,
        c.consumed,
        c.misses,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_prints_without_panic() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        t.print();
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_bytes(500), "500B");
        assert!(fmt_bytes(1500).ends_with("KB"));
        assert!(fmt_secs(0.5).ends_with("ms"));
        assert!(fmt_secs(200.0).ends_with("min"));
    }

    #[test]
    fn time_reps_counts() {
        let v = time_reps(1, 3, || {});
        assert_eq!(v.len(), 3);
    }
}
