//! Minimal bench harness (criterion is unavailable offline): warmup +
//! repeated timing with mean/stddev, and aligned table printing for the
//! paper's tables and figure series.

use crate::util::stats;
use std::time::Instant;

/// Time `f` over `reps` repetitions after `warmup` runs; returns seconds per rep.
pub fn time_reps(warmup: usize, reps: usize, mut f: impl FnMut()) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect()
}

/// A printable results table.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn print(&self) {
        println!("\n== {} ==", self.title);
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:>w$}  ", c, w = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        for row in &self.rows {
            line(row);
        }
    }
}

/// Format seconds adaptively.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else if s < 120.0 {
        format!("{:.2}s", s)
    } else {
        format!("{:.2}min", s / 60.0)
    }
}

/// Format bytes adaptively.
pub fn fmt_bytes(b: u64) -> String {
    let bf = b as f64;
    if bf < 1e3 {
        format!("{b}B")
    } else if bf < 1e6 {
        format!("{:.1}KB", bf / 1e3)
    } else if bf < 1e9 {
        format!("{:.1}MB", bf / 1e6)
    } else {
        format!("{:.2}GB", bf / 1e9)
    }
}

/// Summarize reps as "mean ± std".
pub fn summarize(xs: &[f64]) -> String {
    format!("{} ± {}", fmt_secs(stats::mean(xs)), fmt_secs(stats::stddev(xs)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_prints_without_panic() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        t.print();
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_bytes(500), "500B");
        assert!(fmt_bytes(1500).ends_with("KB"));
        assert!(fmt_secs(0.5).ends_with("ms"));
        assert!(fmt_secs(200.0).ends_with("min"));
    }

    #[test]
    fn time_reps_counts() {
        let v = time_reps(1, 3, || {});
        assert_eq!(v.len(), 3);
    }
}
