//! Garbled-circuit cluster assignment (the M-Kmeans core step).
//!
//! Party 0 garbles one argmin circuit per sample (fresh labels each) and
//! masks the one-hot outputs with random bits — its boolean share. The
//! evaluator obtains its input labels through OT extension, evaluates,
//! and decodes the masked outputs — the other boolean share. Distances
//! enter as the low `w` bits of each party's additive share (exact:
//! 2^64 ≡ 0 mod 2^w, and |D'| < 2^{w−1}).

use crate::gc::builder::assign_circuit;
use crate::gc::garble::{decode, evaluate, garble};

use crate::net::Chan;
use crate::offline::iknp::{IknpReceiver, IknpSender};
use crate::ring::matrix::Mat;
use crate::ss::boolean::BoolShare;
use crate::util::prng::Prg;

/// Share-bit width fed into the circuit (|D'| < 2^47 at scale 2f).
pub const GC_WIDTH: usize = 48;

fn share_bits(share: &Mat, row: usize, w: usize) -> Vec<bool> {
    // k words of w bits, LSB first, one word per cluster column.
    let k = share.cols;
    let mut out = Vec::with_capacity(k * w);
    for j in 0..k {
        let v = share.at(row, j);
        for b in 0..w {
            out.push((v >> b) & 1 == 1);
        }
    }
    out
}

/// Garbler side (party 0): `d` is its share of the distance matrix
/// (n×k). Returns its boolean share of the one-hot assignment (n·k
/// lanes, row-major).
pub fn garbler(chan: &mut Chan, ot: &mut IknpSender, d: &Mat, prg: &mut Prg) -> BoolShare {
    let (n, k) = (d.rows, d.cols);
    let circ = assign_circuit(k, GC_WIDTH);
    let mut my_share = BoolShare::zeros(n * k);

    // Garble all samples, collecting tables + garbler labels + masked
    // decode bits into one frame, and the evaluator's label pairs for OT.
    let mut frame: Vec<u8> = Vec::new();
    frame.extend_from_slice(&(circ.and_count() as u64).to_le_bytes());
    let mut ot_pairs: Vec<(Vec<u8>, Vec<u8>)> = Vec::with_capacity(n * k * GC_WIDTH);
    for i in 0..n {
        let gb = garble(&circ, prg);
        for (tg, te) in &gb.tables {
            frame.extend_from_slice(&tg.to_le_bytes());
            frame.extend_from_slice(&te.to_le_bytes());
        }
        let glabels = gb.garbler_labels(&circ, &share_bits(d, i, GC_WIDTH));
        for l in &glabels {
            frame.extend_from_slice(&l.to_le_bytes());
        }
        // Masked decode bits: mask = my boolean share.
        for (j, &db) in gb.decode.iter().enumerate() {
            let m = prg.next_u64() & 1 == 1;
            my_share.set(i * k + j, m);
            frame.push((db ^ m) as u8);
        }
        // Evaluator input label pairs for this sample's OTs.
        for b in 0..circ.n_eval {
            let (l0, l1) = gb.labels(circ.eval_input(b));
            ot_pairs.push((l0.to_le_bytes().to_vec(), l1.to_le_bytes().to_vec()));
        }
    }
    chan.send_bytes(&frame);
    ot.send(chan, &ot_pairs, 16);
    my_share
}

/// Evaluator side (party 1): returns its boolean share of the one-hot
/// assignment.
pub fn evaluator(chan: &mut Chan, ot: &mut IknpReceiver, d: &Mat, prg: &mut Prg) -> BoolShare {
    let _ = prg;
    let (n, k) = (d.rows, d.cols);
    let circ = assign_circuit(k, GC_WIDTH);
    let frame = chan.recv_bytes();
    let and_count = u64::from_le_bytes(frame[..8].try_into().unwrap()) as usize;
    assert_eq!(and_count, circ.and_count(), "circuit mismatch");
    let per_sample = and_count * 32 + (1 + circ.n_garbler) * 16 + k;
    assert_eq!(frame.len(), 8 + n * per_sample, "gc frame size");

    // OT choices: all samples' share bits.
    let mut choices = Vec::with_capacity(n * circ.n_eval);
    for i in 0..n {
        choices.extend(share_bits(d, i, GC_WIDTH));
    }
    let labels = ot.recv(chan, &choices, 16);

    let mut out = BoolShare::zeros(n * k);
    for i in 0..n {
        let base = 8 + i * per_sample;
        let mut tables = Vec::with_capacity(and_count);
        for g in 0..and_count {
            let off = base + g * 32;
            let tg = u128::from_le_bytes(frame[off..off + 16].try_into().unwrap());
            let te = u128::from_le_bytes(frame[off + 16..off + 32].try_into().unwrap());
            tables.push((tg, te));
        }
        let mut input_labels = Vec::with_capacity(1 + circ.n_garbler + circ.n_eval);
        let goff = base + and_count * 32;
        for b in 0..1 + circ.n_garbler {
            let off = goff + b * 16;
            input_labels.push(u128::from_le_bytes(frame[off..off + 16].try_into().unwrap()));
        }
        for b in 0..circ.n_eval {
            let l = &labels[i * circ.n_eval + b];
            input_labels.push(u128::from_le_bytes(l.as_slice().try_into().unwrap()));
        }
        let out_labels = evaluate(&circ, &tables, &input_labels);
        let doff = goff + (1 + circ.n_garbler) * 16;
        let masked_decode: Vec<bool> = (0..k).map(|j| frame[doff + j] == 1).collect();
        let bits = decode(&out_labels, &masked_decode);
        for (j, &b) in bits.iter().enumerate() {
            out.set(i * k + j, b);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::duplex_pair;
    use crate::offline::iknp::{setup_receiver, setup_sender};
    use crate::ring::fixed::encode_f64;
    use crate::ss::share::split;
    use std::thread;

    #[test]
    fn gc_assignment_matches_plain_argmin() {
        let (n, k) = (7, 5);
        let mut prg = Prg::new(88);
        // Distances at scale 2f-ish magnitudes, some negative.
        let dvals: Vec<f64> = (0..n * k).map(|_| prg.next_f64() * 10.0 - 3.0).collect();
        let enc: Vec<u64> = dvals.iter().map(|&v| encode_f64(v)).collect();
        let d = Mat::from_vec(n, k, enc);
        let (d0, d1) = split(&d, &mut prg);

        let (mut c0, mut c1) = duplex_pair();
        let h = thread::spawn(move || {
            let mut prg = Prg::new(91);
            let mut ot = setup_sender(&mut c0, &mut prg);
            let s = garbler(&mut c0, &mut ot, &d0, &mut prg);
            s.words
        });
        let mut prg1 = Prg::new(92);
        let mut ot = setup_receiver(&mut c1, &mut prg1);
        let s1 = evaluator(&mut c1, &mut ot, &d1, &mut prg1);
        let w0 = h.join().unwrap();
        for i in 0..n {
            let row = &dvals[i * k..(i + 1) * k];
            let want = row
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            for j in 0..k {
                let lane = i * k + j;
                let bit = ((w0[lane / 64] ^ s1.words[lane / 64]) >> (lane % 64)) & 1 == 1;
                assert_eq!(bit, j == want, "sample {i} col {j}");
            }
        }
    }
}
