//! The full M-Kmeans protocol loop (vertical partitioning).
//!
//! Per iteration: SS distance (triples generated **inline** with OT — no
//! offline phase, the paper's critique #1), garbled-circuit argmin
//! ([`super::gcmin`]), B2A of the boolean one-hot, and the shared
//! centroid update with secure division. All traffic and wall-clock is
//! one online timeline.

use crate::data::blobs::Dataset;
use crate::kmeans::config::Partition;
use crate::kmeans::secure::split_dataset;
use crate::kmeans::{esd, init, update};
use crate::net::{duplex_pair, Chan, Meter};
use crate::offline::gilboa::OtTripleGen;
use crate::offline::iknp::{setup_receiver, setup_sender, IknpReceiver, IknpSender};
use crate::ring::matrix::Mat;
use crate::runtime::pool::run_pair;
use crate::ss::boolean::b2a;
use crate::ss::share::reconstruct;
use crate::ss::{Session, SessionOptions};
use crate::util::error::{Error, Result};
use crate::util::prng::Prg;
use crate::util::timer::timed;

/// M-Kmeans run parameters.
#[derive(Debug, Clone)]
pub struct MkmeansConfig {
    pub k: usize,
    pub iters: usize,
    pub seed: u128,
    /// Vertical feature split (the comparison setting of the paper).
    pub d_a: usize,
}

impl Default for MkmeansConfig {
    fn default() -> Self {
        MkmeansConfig { k: 2, iters: 10, seed: 0xCAFE, d_a: 1 }
    }
}

/// Results + measurements of one M-Kmeans run.
#[derive(Debug)]
pub struct MkmeansOutput {
    pub centroids: Vec<f64>,
    pub assignments: Vec<usize>,
    pub k: usize,
    pub d: usize,
    /// Total bytes sent (both parties, protocol + inline OT channels).
    pub bytes_total: u64,
    /// Rounds on the protocol channel (flights).
    pub rounds: u64,
    /// Wall-clock seconds (single timeline: no offline split).
    pub wall_secs: f64,
    pub meter_a: Meter,
    pub meter_b: Meter,
}

enum OtEnd {
    Sender(IknpSender),
    Receiver(IknpReceiver),
}

#[allow(clippy::too_many_arguments)]
fn party_main(
    chan: &mut Chan,
    ot_chan: Chan,
    x_mine: Mat,
    n: usize,
    d: usize,
    cfg: &MkmeansConfig,
) -> (Mat, Vec<usize>, Meter) {
    let party = chan.party;
    // Inline OT triple generation — this *is* the online phase.
    let mut ts = OtTripleGen::new(ot_chan, cfg.seed ^ 0x517);
    // A second OT endpoint on the protocol channel for GC labels.
    let mut prg = Prg::new(cfg.seed ^ ((party as u128) << 32) ^ 0x929);
    chan.set_phase("online.gc-baseot");
    let mut gc_ot = if party == 0 {
        OtEnd::Sender(setup_sender(chan, &mut prg))
    } else {
        OtEnd::Receiver(setup_receiver(chan, &mut prg))
    };

    chan.set_phase("online.init");
    let mut mu = init::vertical(&x_mine, cfg.d_a, d, n, cfg.k, cfg.seed, party);
    let mut c_arith = Mat::zeros(n, cfg.k);

    for _t in 0..cfg.iters {
        // Distance (same vectorized math; triples inline).
        chan.set_phase("online.s1");
        let dmat = {
            let mut ctx = Session::new(chan, &mut ts, Prg::new(cfg.seed ^ 0x31), SessionOptions::default());
            esd::vertical(&mut ctx, &x_mine, &mu, cfg.d_a)
        };

        // GC argmin → boolean one-hot shares.
        chan.set_phase("online.s2-gc");
        let bool_share = match &mut gc_ot {
            OtEnd::Sender(s) => super::gcmin::garbler(chan, s, &dmat, &mut prg),
            OtEnd::Receiver(r) => super::gcmin::evaluator(chan, r, &dmat, &mut prg),
        };
        // B2A lift.
        let c_lifted = {
            let mut ctx = Session::new(chan, &mut ts, Prg::new(cfg.seed ^ 0x32), SessionOptions::default());
            b2a(&mut ctx, &bool_share)
        };
        c_arith = Mat::from_vec(n, cfg.k, c_lifted.data);

        // Update.
        chan.set_phase("online.s3");
        let mu_new = {
            let mut ctx = Session::new(chan, &mut ts, Prg::new(cfg.seed ^ 0x33), SessionOptions::default());
            let num = update::numerator_vertical(&mut ctx, &x_mine, &c_arith, cfg.d_a, d);
            update::finish_update(&mut ctx, &num, &c_arith, &mu)
        };
        mu = mu_new;
    }

    chan.set_phase("reveal");
    let mu_plain = reconstruct(chan, &mu);
    let c_plain = reconstruct(chan, &c_arith);
    let assignments = (0..n)
        .map(|i| (0..cfg.k).find(|&j| c_plain.at(i, j) == 1).unwrap_or(0))
        .collect();
    (mu_plain, assignments, ts.into_meter())
}

/// Run M-Kmeans on a vertically partitioned dataset.
pub fn run_vertical(data: &Dataset, cfg: &MkmeansConfig) -> Result<MkmeansOutput> {
    if cfg.d_a == 0 || cfg.d_a >= data.d {
        return Err(Error::Config("need 0 < d_a < d".into()));
    }
    let (xa, xb) = split_dataset(data, Partition::Vertical { d_a: cfg.d_a });
    let (n, d) = (data.n, data.d);
    let (mut p0, mut p1) = duplex_pair();
    let (o0, o1) = duplex_pair();
    let cfg_a = cfg.clone();
    let cfg_b = cfg.clone();
    let (((ra, ma), (rb, mb)), wall) = timed(|| {
        run_pair(
            move || {
                let r = party_main(&mut p0, o0, xa, n, d, &cfg_a);
                (r, p0.into_meter())
            },
            move || {
                let r = party_main(&mut p1, o1, xb, n, d, &cfg_b);
                (r, p1.into_meter())
            },
        )
    });
    let (mu, assignments, ot_meter_a) = ra;
    let (_mu_b, _assign_b, ot_meter_b) = rb;
    let bytes_total = ma.total().bytes_sent
        + mb.total().bytes_sent
        + ot_meter_a.total().bytes_sent
        + ot_meter_b.total().bytes_sent;
    Ok(MkmeansOutput {
        centroids: mu.decode(),
        assignments,
        k: cfg.k,
        d,
        bytes_total,
        rounds: ma.total().rounds + ot_meter_a.total().rounds,
        wall_secs: wall,
        meter_a: ma,
        meter_b: mb,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::blobs::BlobSpec;
    use crate::kmeans::plaintext;

    #[test]
    fn mkmeans_matches_plaintext_trajectory() {
        let mut spec = BlobSpec::new(16, 2, 2);
        spec.spread = 0.02;
        let ds = spec.generate(61);
        let cfg = MkmeansConfig { k: 2, iters: 2, d_a: 1, ..Default::default() };
        let out = run_vertical(&ds, &cfg).unwrap();
        let plain = plaintext::kmeans(&ds, 2, 2, cfg.seed);
        assert_eq!(out.assignments, plain.assignments);
        for i in 0..out.centroids.len() {
            assert!(
                (out.centroids[i] - plain.centroids[i]).abs() < 1e-2,
                "centroid {i}"
            );
        }
    }
}
