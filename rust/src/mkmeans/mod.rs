//! M-Kmeans: the Mohassel-Rosulek-Trieu (PoPETs 2020) baseline,
//! reimplemented on this crate's substrate for apples-to-apples
//! comparison (paper §5, Tables 1-2, Q5).
//!
//! Protocol shape per the original: secret-shared distance computation,
//! a **customized garbled circuit** computing binary shares of the
//! argmin ([`gcmin`]), and a shared centroid update. The two structural
//! differences the paper exploits are preserved faithfully:
//!
//! 1. **No offline phase** — every multiplication triple is generated
//!    inline with OT during the online timeline;
//! 2. **GC assignment** — per-sample garbled argmin instead of the
//!    vectorized secret-shared comparison tree.

pub mod gcmin;
pub mod protocol;

pub use protocol::{run_vertical, MkmeansConfig, MkmeansOutput};
