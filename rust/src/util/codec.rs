//! Shared little-endian framing helpers for persisted artifacts.
//!
//! The model artifact (`PPKMDL01`, [`crate::serve::model`]) and the
//! resume checkpoint (`PPKMCKP1`, [`crate::resume`]) follow one framing
//! discipline: magic + version header, fixed-width little-endian fields,
//! and a trailing FNV-1a checksum over every preceding byte. The
//! encoders and bounds-checked readers live here so the two formats
//! cannot drift in how they serialize or how they fail — every reader
//! returns a typed [`Error::Config`] naming the artifact, never a panic
//! (`no-panic-in-wire-paths` covers the resume subtree).

// Artifact parsers handle untrusted bytes: typed errors only.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use crate::util::error::{Error, Result};

/// FNV-1a over a byte slice — the artifact trailer checksum. Detects
/// corruption (bit flips, truncation); it is *not* tamper-resistant,
/// which is why parsers also bound every header-derived length.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Append a `u32` little-endian.
pub fn push_u32(out: &mut Vec<u8>, x: u32) {
    out.extend_from_slice(&x.to_le_bytes());
}

/// Append a `u64` little-endian.
pub fn push_u64(out: &mut Vec<u8>, x: u64) {
    out.extend_from_slice(&x.to_le_bytes());
}

/// Append an `f64` as its IEEE-754 bit pattern, little-endian.
pub fn push_f64(out: &mut Vec<u8>, x: f64) {
    push_u64(out, x.to_bits());
}

/// Append a length-prefixed (u32) byte string.
pub fn push_bytes(out: &mut Vec<u8>, b: &[u8]) {
    push_u32(out, b.len() as u32);
    out.extend_from_slice(b);
}

/// Append a length-prefixed UTF-8 string.
pub fn push_str(out: &mut Vec<u8>, s: &str) {
    push_bytes(out, s.as_bytes());
}

fn truncated(what: &str, kind: &str) -> Error {
    Error::Config(format!("{what}: truncated ({kind})"))
}

/// Read a `u32`, advancing `off`; `what` names the artifact in errors.
pub fn rd_u32(b: &[u8], off: &mut usize, what: &str) -> Result<u32> {
    let end = off.checked_add(4).filter(|&e| e <= b.len()).ok_or_else(|| truncated(what, "u32"))?;
    let mut w = [0u8; 4];
    w.copy_from_slice(&b[*off..end]);
    *off = end;
    Ok(u32::from_le_bytes(w))
}

/// Read a `u64`, advancing `off`.
pub fn rd_u64(b: &[u8], off: &mut usize, what: &str) -> Result<u64> {
    let end = off.checked_add(8).filter(|&e| e <= b.len()).ok_or_else(|| truncated(what, "u64"))?;
    let mut w = [0u8; 8];
    w.copy_from_slice(&b[*off..end]);
    *off = end;
    Ok(u64::from_le_bytes(w))
}

/// Read an `f64` (IEEE-754 bits), advancing `off`.
pub fn rd_f64(b: &[u8], off: &mut usize, what: &str) -> Result<f64> {
    Ok(f64::from_bits(rd_u64(b, off, what)?))
}

/// Read a u32-length-prefixed byte string, advancing `off`. The length
/// is bounds-checked against the remaining input *before* allocation, so
/// a forged header cannot trigger a huge reservation.
pub fn rd_bytes(b: &[u8], off: &mut usize, what: &str) -> Result<Vec<u8>> {
    let len = rd_u32(b, off, what)? as usize;
    let end =
        off.checked_add(len).filter(|&e| e <= b.len()).ok_or_else(|| truncated(what, "bytes"))?;
    let v = b[*off..end].to_vec();
    *off = end;
    Ok(v)
}

/// Read a u32-length-prefixed UTF-8 string, advancing `off`.
pub fn rd_str(b: &[u8], off: &mut usize, what: &str) -> Result<String> {
    let v = rd_bytes(b, off, what)?;
    String::from_utf8(v).map_err(|_| Error::Config(format!("{what}: non-UTF-8 string field")))
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;

    #[test]
    fn scalar_roundtrips() {
        let mut out = Vec::new();
        push_u32(&mut out, 7);
        push_u64(&mut out, u64::MAX - 1);
        push_f64(&mut out, -0.125);
        push_str(&mut out, "serve.batch.3");
        let mut off = 0;
        assert_eq!(rd_u32(&out, &mut off, "t").unwrap(), 7);
        assert_eq!(rd_u64(&out, &mut off, "t").unwrap(), u64::MAX - 1);
        assert_eq!(rd_f64(&out, &mut off, "t").unwrap(), -0.125);
        assert_eq!(rd_str(&out, &mut off, "t").unwrap(), "serve.batch.3");
        assert_eq!(off, out.len());
    }

    #[test]
    fn truncation_is_a_typed_error_naming_the_artifact() {
        let mut out = Vec::new();
        push_u64(&mut out, 42);
        let mut off = 0;
        let err = rd_u64(&out[..5], &mut off, "checkpoint artifact").unwrap_err();
        assert!(err.to_string().contains("checkpoint artifact"), "{err}");
        // A length prefix pointing past the buffer is refused before any
        // allocation sized from it.
        let mut forged = Vec::new();
        push_u32(&mut forged, u32::MAX);
        let mut off = 0;
        assert!(rd_bytes(&forged, &mut off, "t").is_err());
    }

    #[test]
    fn fnv_is_stable() {
        // Pinned value: the checksum is part of two on-disk formats.
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
    }
}
