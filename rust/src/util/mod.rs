//! Low-level utilities: error types, PRNG, timing, statistics.

pub mod error;
pub mod prng;
pub mod stats;
pub mod timer;
