//! Low-level utilities: error types, block cipher, PRNG, timing,
//! statistics.

pub mod cipher;
pub mod codec;
pub mod error;
pub mod hash;
pub mod prng;
pub mod stats;
pub mod timer;
