//! Low-level utilities: error types, block cipher, PRNG, timing,
//! statistics.

pub mod cipher;
pub mod error;
pub mod hash;
pub mod prng;
pub mod stats;
pub mod timer;
