//! Small statistics helpers used by the bench harness and evaluation.

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Median (sorts a copy).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Relative error |a-b| / max(|b|, eps).
pub fn rel_err(a: f64, b: f64) -> f64 {
    (a - b).abs() / b.abs().max(1e-12)
}

/// Maximum absolute elementwise difference of two equal-length slices.
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median_stddev() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
        assert!((median(&[1.0, 5.0, 2.0]) - 2.0).abs() < 1e-12);
        assert!((stddev(&xs) - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn diffs() {
        assert!((max_abs_diff(&[1.0, 2.0], &[1.5, 2.0]) - 0.5).abs() < 1e-12);
        assert!(rel_err(1.01, 1.0) < 0.011);
    }
}
