//! A 256-bit Merkle–Damgård hash over the in-repo Speck-128/128
//! permutation (Davies–Meyer mode, two independent lanes).
//!
//! Stand-in for SHA-256 so the crate stays dependency-free in an
//! offline container — the same substitution policy as Speck-for-AES in
//! [`crate::util::cipher`]. (The seed code imported the external `sha2`
//! crate here without declaring it, which could never build offline.)
//! Both uses are *local key derivation* where the two parties must
//! simply agree on the function: hashing Diffie-Hellman group elements
//! to base-OT seeds ([`crate::offline::baseot`]) and the
//! correlation-robust row-key mask of the IKNP extension
//! ([`crate::offline::iknp`]). For a production deployment swap this
//! module for hardware SHA-256; every caller goes through [`Hash256`].
//!
//! Construction: two 128-bit chaining lanes with distinct IVs; each
//! 16-byte message block `B` updates every lane `s` as
//! `s ← E_B(s) ⊕ s` (Davies–Meyer with the block as the cipher key),
//! with standard length-strengthening (an `0x80` marker byte, zero
//! padding, and a final block carrying the total bit length).

use crate::util::cipher::{Speck128, SpeckMulti};

/// Streaming 256-bit hash: `new` → any number of `update`s →
/// `finalize`.
pub struct Hash256 {
    state: [u128; 2],
    buf: [u8; 16],
    buf_len: usize,
    total_bytes: u64,
}

/// Distinct lane IVs (digits of π and e — nothing-up-my-sleeve).
const IV: [u128; 2] = [
    0x243F_6A88_85A3_08D3_1319_8A2E_0370_7344,
    0x9E37_79B9_7F4A_7C15_F39C_C060_5CED_C834,
];

impl Hash256 {
    /// A fresh hash state.
    pub fn new() -> Hash256 {
        Hash256 { state: IV, buf: [0u8; 16], buf_len: 0, total_bytes: 0 }
    }

    fn compress(state: &mut [u128; 2], block: &[u8; 16]) {
        let cipher = Speck128::new(*block);
        for s in state.iter_mut() {
            *s ^= cipher.encrypt_u128(*s);
        }
    }

    /// Absorb more input (any `&[u8]`-like value).
    pub fn update(&mut self, data: impl AsRef<[u8]>) {
        let mut data = data.as_ref();
        self.total_bytes = self.total_bytes.wrapping_add(data.len() as u64);
        // Top up a partial block first.
        if self.buf_len > 0 {
            let take = (16 - self.buf_len).min(data.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&data[..take]);
            self.buf_len += take;
            data = &data[take..];
            if self.buf_len == 16 {
                let block = self.buf;
                Self::compress(&mut self.state, &block);
                self.buf_len = 0;
            }
        }
        while data.len() >= 16 {
            let block: [u8; 16] = data[..16].try_into().unwrap();
            Self::compress(&mut self.state, &block);
            data = &data[16..];
        }
        // Only overwrite the buffer when bytes actually remain: if the
        // top-up branch consumed all of `data` without completing a
        // block, `buf_len` still counts buffered bytes that must not be
        // discarded. When `data` is non-empty here, `buf_len` is
        // provably 0 (the top-up either filled and flushed the block or
        // ate the whole input).
        if !data.is_empty() {
            self.buf[..data.len()].copy_from_slice(data);
            self.buf_len = data.len();
        }
    }

    /// Pad, absorb the length block, and return the 32-byte digest.
    pub fn finalize(mut self) -> [u8; 32] {
        // 0x80 marker + zero padding to a block boundary.
        let mut tail = [0u8; 16];
        tail[..self.buf_len].copy_from_slice(&self.buf[..self.buf_len]);
        tail[self.buf_len] = 0x80;
        Self::compress(&mut self.state, &tail);
        // Length-strengthening block: total bit length, domain-marked.
        let mut len_block = [0u8; 16];
        len_block[..8].copy_from_slice(&(self.total_bytes.wrapping_mul(8)).to_le_bytes());
        len_block[8..].copy_from_slice(b"ppk-h256");
        Self::compress(&mut self.state, &len_block);
        let mut out = [0u8; 32];
        out[..16].copy_from_slice(&self.state[0].to_le_bytes());
        out[16..].copy_from_slice(&self.state[1].to_le_bytes());
        out
    }
}

impl Default for Hash256 {
    fn default() -> Self {
        Hash256::new()
    }
}

/// One-shot convenience over [`Hash256`].
pub fn hash256(data: &[u8]) -> [u8; 32] {
    let mut h = Hash256::new();
    h.update(data);
    h.finalize()
}

/// Hash a batch of **equal-length** messages in lockstep, packing
/// [`crate::runtime::simd::global_lanes`] messages per compression
/// sweep.
///
/// Equal lengths mean every message is at the same block position at
/// every step, so one [`SpeckMulti`] instance per block position (the
/// `N` messages' blocks are its `N` keys) carries all lanes through the
/// identical Davies–Meyer schedule — padding, marker and length block
/// included. This is the per-OT mask batch of the IKNP extension, where
/// every hash input is a fixed 24-byte `(index, row key)` pair.
/// Bit-identical to calling [`hash256`] per message at every lane
/// width; ragged batch tails fall back to the scalar path.
pub fn hash256_many(msgs: &[&[u8]]) -> Vec<[u8; 32]> {
    if msgs.is_empty() {
        return vec![];
    }
    let len = msgs[0].len();
    assert!(
        msgs.iter().all(|m| m.len() == len),
        "hash256_many requires equal-length messages"
    );
    let lanes = crate::runtime::simd::global_lanes();
    let mut out = Vec::with_capacity(msgs.len());
    let mut i = 0;
    if lanes >= 8 {
        while i + 8 <= msgs.len() {
            let chunk: &[&[u8]; 8] = msgs[i..i + 8].try_into().unwrap();
            out.extend_from_slice(&hash256_lockstep::<8>(chunk));
            i += 8;
        }
    }
    if lanes >= 4 {
        while i + 4 <= msgs.len() {
            let chunk: &[&[u8]; 4] = msgs[i..i + 4].try_into().unwrap();
            out.extend_from_slice(&hash256_lockstep::<4>(chunk));
            i += 4;
        }
    }
    while i < msgs.len() {
        out.push(hash256(msgs[i]));
        i += 1;
    }
    out
}

/// One Davies–Meyer step across `N` lanes: every lane's state words are
/// encrypted under that lane's block-key and XORed back.
fn compress_lockstep<const N: usize>(
    s0: &mut [u128; N],
    s1: &mut [u128; N],
    blocks: &[[u8; 16]; N],
) {
    let cipher = SpeckMulti::new(blocks);
    let e0 = cipher.encrypt_u128s(s0);
    let e1 = cipher.encrypt_u128s(s1);
    for lane in 0..N {
        s0[lane] ^= e0[lane];
        s1[lane] ^= e1[lane];
    }
}

/// `N` equal-length messages through the full [`Hash256`] schedule in
/// lockstep.
fn hash256_lockstep<const N: usize>(msgs: &[&[u8]; N]) -> [[u8; 32]; N] {
    let len = msgs[0].len();
    let mut s0 = [IV[0]; N];
    let mut s1 = [IV[1]; N];
    for b in 0..len / 16 {
        let mut blocks = [[0u8; 16]; N];
        for lane in 0..N {
            blocks[lane].copy_from_slice(&msgs[lane][b * 16..(b + 1) * 16]);
        }
        compress_lockstep(&mut s0, &mut s1, &blocks);
    }
    // 0x80 marker + zero padding (always present, exactly like
    // Hash256::finalize — a full-block message still gets a tail block).
    let rem = len % 16;
    let mut blocks = [[0u8; 16]; N];
    for lane in 0..N {
        blocks[lane][..rem].copy_from_slice(&msgs[lane][len - rem..]);
        blocks[lane][rem] = 0x80;
    }
    compress_lockstep(&mut s0, &mut s1, &blocks);
    // Length-strengthening block (identical across lanes).
    let mut len_block = [0u8; 16];
    len_block[..8].copy_from_slice(&(len as u64).wrapping_mul(8).to_le_bytes());
    len_block[8..].copy_from_slice(b"ppk-h256");
    compress_lockstep(&mut s0, &mut s1, &[len_block; N]);
    let mut out = [[0u8; 32]; N];
    for lane in 0..N {
        out[lane][..16].copy_from_slice(&s0[lane].to_le_bytes());
        out[lane][16..].copy_from_slice(&s1[lane].to_le_bytes());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_input_sensitive() {
        assert_eq!(hash256(b"abc"), hash256(b"abc"));
        assert_ne!(hash256(b"abc"), hash256(b"abd"));
        assert_ne!(hash256(b""), hash256(b"\0"));
    }

    #[test]
    fn streaming_matches_one_shot() {
        let data: Vec<u8> = (0..123u8).collect();
        for split in [0usize, 1, 15, 16, 17, 64, 123] {
            let mut h = Hash256::new();
            h.update(&data[..split]);
            h.update(&data[split..]);
            assert_eq!(h.finalize(), hash256(&data), "split at {split}");
        }
    }

    #[test]
    fn short_follow_up_updates_keep_buffered_bytes() {
        // Regression: a later update shorter than the block remainder
        // (including empty) must not clobber the partial-block buffer.
        let mut h = Hash256::new();
        h.update(b"a");
        h.update(b"b");
        assert_eq!(h.finalize(), hash256(b"ab"));
        let mut h = Hash256::new();
        h.update(b"0123456789");
        h.update(b"");
        h.update(b"ab");
        assert_eq!(h.finalize(), hash256(b"0123456789ab"));
    }

    #[test]
    fn length_extension_padding_separates_prefixes() {
        // "aa" + "" must differ from "a" + "a"-with-boundary tricks: the
        // length block separates messages of equal padded content.
        let a = hash256(&[0x80]);
        let b = hash256(&[]);
        assert_ne!(a, b, "marker byte must not collide with empty input");
    }

    #[test]
    fn lockstep_batch_matches_per_message_hash() {
        use crate::runtime::simd::set_global_lanes;
        // Lengths straddling block boundaries; batch sizes with ragged
        // tails (batch % lanes != 0) — the rot spot for packed kernels.
        for len in [0usize, 1, 15, 16, 17, 24, 32, 47] {
            for count in [1usize, 3, 4, 5, 8, 11, 16] {
                let msgs: Vec<Vec<u8>> = (0..count)
                    .map(|i| (0..len).map(|j| (i * 31 + j) as u8).collect())
                    .collect();
                let refs: Vec<&[u8]> = msgs.iter().map(|m| m.as_slice()).collect();
                let want: Vec<[u8; 32]> = msgs.iter().map(|m| hash256(m)).collect();
                for width in [1usize, 4, 8] {
                    set_global_lanes(width);
                    assert_eq!(
                        hash256_many(&refs),
                        want,
                        "len={len} count={count} width={width}"
                    );
                }
                set_global_lanes(1);
            }
        }
    }

    #[test]
    #[should_panic(expected = "equal-length")]
    fn lockstep_batch_rejects_ragged_lengths() {
        let a = [1u8; 3];
        let b = [2u8; 4];
        hash256_many(&[&a, &b]);
    }

    #[test]
    fn avalanche_is_plausible() {
        let a = hash256(b"correlation robust");
        let b = hash256(b"correlation robusu");
        let diff: u32 = a.iter().zip(&b).map(|(x, y)| (x ^ y).count_ones()).sum();
        assert!(diff > 80, "only {diff} differing bits");
    }
}
