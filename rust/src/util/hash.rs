//! A 256-bit Merkle–Damgård hash over the in-repo Speck-128/128
//! permutation (Davies–Meyer mode, two independent lanes).
//!
//! Stand-in for SHA-256 so the crate stays dependency-free in an
//! offline container — the same substitution policy as Speck-for-AES in
//! [`crate::util::cipher`]. (The seed code imported the external `sha2`
//! crate here without declaring it, which could never build offline.)
//! Both uses are *local key derivation* where the two parties must
//! simply agree on the function: hashing Diffie-Hellman group elements
//! to base-OT seeds ([`crate::offline::baseot`]) and the
//! correlation-robust row-key mask of the IKNP extension
//! ([`crate::offline::iknp`]). For a production deployment swap this
//! module for hardware SHA-256; every caller goes through [`Hash256`].
//!
//! Construction: two 128-bit chaining lanes with distinct IVs; each
//! 16-byte message block `B` updates every lane `s` as
//! `s ← E_B(s) ⊕ s` (Davies–Meyer with the block as the cipher key),
//! with standard length-strengthening (an `0x80` marker byte, zero
//! padding, and a final block carrying the total bit length).

use crate::util::cipher::Speck128;

/// Streaming 256-bit hash: `new` → any number of `update`s →
/// `finalize`.
pub struct Hash256 {
    state: [u128; 2],
    buf: [u8; 16],
    buf_len: usize,
    total_bytes: u64,
}

/// Distinct lane IVs (digits of π and e — nothing-up-my-sleeve).
const IV: [u128; 2] = [
    0x243F_6A88_85A3_08D3_1319_8A2E_0370_7344,
    0x9E37_79B9_7F4A_7C15_F39C_C060_5CED_C834,
];

impl Hash256 {
    /// A fresh hash state.
    pub fn new() -> Hash256 {
        Hash256 { state: IV, buf: [0u8; 16], buf_len: 0, total_bytes: 0 }
    }

    fn compress(state: &mut [u128; 2], block: &[u8; 16]) {
        let cipher = Speck128::new(*block);
        for s in state.iter_mut() {
            *s ^= cipher.encrypt_u128(*s);
        }
    }

    /// Absorb more input (any `&[u8]`-like value).
    pub fn update(&mut self, data: impl AsRef<[u8]>) {
        let mut data = data.as_ref();
        self.total_bytes = self.total_bytes.wrapping_add(data.len() as u64);
        // Top up a partial block first.
        if self.buf_len > 0 {
            let take = (16 - self.buf_len).min(data.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&data[..take]);
            self.buf_len += take;
            data = &data[take..];
            if self.buf_len == 16 {
                let block = self.buf;
                Self::compress(&mut self.state, &block);
                self.buf_len = 0;
            }
        }
        while data.len() >= 16 {
            let block: [u8; 16] = data[..16].try_into().unwrap();
            Self::compress(&mut self.state, &block);
            data = &data[16..];
        }
        // Only overwrite the buffer when bytes actually remain: if the
        // top-up branch consumed all of `data` without completing a
        // block, `buf_len` still counts buffered bytes that must not be
        // discarded. When `data` is non-empty here, `buf_len` is
        // provably 0 (the top-up either filled and flushed the block or
        // ate the whole input).
        if !data.is_empty() {
            self.buf[..data.len()].copy_from_slice(data);
            self.buf_len = data.len();
        }
    }

    /// Pad, absorb the length block, and return the 32-byte digest.
    pub fn finalize(mut self) -> [u8; 32] {
        // 0x80 marker + zero padding to a block boundary.
        let mut tail = [0u8; 16];
        tail[..self.buf_len].copy_from_slice(&self.buf[..self.buf_len]);
        tail[self.buf_len] = 0x80;
        Self::compress(&mut self.state, &tail);
        // Length-strengthening block: total bit length, domain-marked.
        let mut len_block = [0u8; 16];
        len_block[..8].copy_from_slice(&(self.total_bytes.wrapping_mul(8)).to_le_bytes());
        len_block[8..].copy_from_slice(b"ppk-h256");
        Self::compress(&mut self.state, &len_block);
        let mut out = [0u8; 32];
        out[..16].copy_from_slice(&self.state[0].to_le_bytes());
        out[16..].copy_from_slice(&self.state[1].to_le_bytes());
        out
    }
}

impl Default for Hash256 {
    fn default() -> Self {
        Hash256::new()
    }
}

/// One-shot convenience over [`Hash256`].
pub fn hash256(data: &[u8]) -> [u8; 32] {
    let mut h = Hash256::new();
    h.update(data);
    h.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_input_sensitive() {
        assert_eq!(hash256(b"abc"), hash256(b"abc"));
        assert_ne!(hash256(b"abc"), hash256(b"abd"));
        assert_ne!(hash256(b""), hash256(b"\0"));
    }

    #[test]
    fn streaming_matches_one_shot() {
        let data: Vec<u8> = (0..123u8).collect();
        for split in [0usize, 1, 15, 16, 17, 64, 123] {
            let mut h = Hash256::new();
            h.update(&data[..split]);
            h.update(&data[split..]);
            assert_eq!(h.finalize(), hash256(&data), "split at {split}");
        }
    }

    #[test]
    fn short_follow_up_updates_keep_buffered_bytes() {
        // Regression: a later update shorter than the block remainder
        // (including empty) must not clobber the partial-block buffer.
        let mut h = Hash256::new();
        h.update(b"a");
        h.update(b"b");
        assert_eq!(h.finalize(), hash256(b"ab"));
        let mut h = Hash256::new();
        h.update(b"0123456789");
        h.update(b"");
        h.update(b"ab");
        assert_eq!(h.finalize(), hash256(b"0123456789ab"));
    }

    #[test]
    fn length_extension_padding_separates_prefixes() {
        // "aa" + "" must differ from "a" + "a"-with-boundary tricks: the
        // length block separates messages of equal padded content.
        let a = hash256(&[0x80]);
        let b = hash256(&[]);
        assert_ne!(a, b, "marker byte must not collide with empty input");
    }

    #[test]
    fn avalanche_is_plausible() {
        let a = hash256(b"correlation robust");
        let b = hash256(b"correlation robusu");
        let diff: u32 = a.iter().zip(&b).map(|(x, y)| (x ^ y).count_ones()).sum();
        assert!(diff > 80, "only {diff} differing bits");
    }
}
