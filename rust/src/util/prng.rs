//! Counter-mode block-cipher pseudo-random generator.
//!
//! The PRG is a *protocol object*, not just a convenience: additive secret
//! sharing derives one share from a PRG seed so only the other share needs
//! to be transmitted, the trusted dealer expands correlated randomness
//! from per-party seeds, and the IKNP OT extension stretches base-OT
//! seeds. The stream is the seed-keyed Speck-128/128 permutation
//! ([`crate::util::cipher`]) of a block counter — the same CTR structure
//! as the classic fixed-key-AES instantiation, with no external crates.

use crate::util::cipher::Speck128;

/// Counter-mode PRG producing a stream of `u64` ring elements / bytes.
#[derive(Clone)]
pub struct Prg {
    cipher: Speck128,
    counter: u128,
    /// Buffered output block (16 bytes = two u64 lanes).
    buf: [u64; 2],
    /// Number of u64 lanes still unread in `buf`.
    avail: usize,
}

impl Prg {
    /// Construct from a 16-byte seed (used as the cipher key).
    pub fn from_seed(seed: [u8; 16]) -> Self {
        let cipher = Speck128::new(seed);
        Prg { cipher, counter: 0, buf: [0; 2], avail: 0 }
    }

    /// Construct from a u128 seed.
    pub fn new(seed: u128) -> Self {
        Prg::from_seed(seed.to_le_bytes())
    }

    /// Deterministically derive an independent child PRG (domain
    /// separation by label), e.g. one per protocol sub-phase.
    pub fn fork(&mut self, label: u64) -> Prg {
        let a = self.next_u64() ^ label.rotate_left(17);
        let b = self.next_u64() ^ label.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Prg::new(((a as u128) << 64) | b as u128)
    }

    #[inline]
    fn refill(&mut self) {
        let mut x = self.counter as u64;
        let mut y = (self.counter >> 64) as u64;
        self.counter = self.counter.wrapping_add(1);
        self.cipher.encrypt_words(&mut x, &mut y);
        self.buf[0] = x;
        self.buf[1] = y;
        self.avail = 2;
    }

    /// Number of `u64` lanes drawn from the stream so far. The stream
    /// state is a pure function of this count: each counter block yields
    /// two lanes, so `position = counter·2 − avail`. Checkpoints persist
    /// this single word and [`Self::skip_to`] restores the exact state.
    pub fn position(&self) -> u64 {
        (self.counter as u64) * 2 - self.avail as u64
    }

    /// Fast-forward a fresh PRG to `position` drawn lanes — O(1), no
    /// replay: the counter jumps directly and at most one block is
    /// re-encrypted to rebuild a half-consumed buffer.
    pub fn skip_to(&mut self, position: u64) {
        self.counter = (position / 2) as u128;
        self.avail = 0;
        if position % 2 == 1 {
            self.refill();
            self.avail = 1;
        }
    }

    /// Next uniformly random `u64`.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        if self.avail == 0 {
            self.refill();
        }
        self.avail -= 1;
        self.buf[self.avail]
    }

    /// Next uniformly random `u128` (e.g. a fresh PRG seed or GC label).
    #[inline]
    pub fn next_u128(&mut self) -> u128 {
        ((self.next_u64() as u128) << 64) | self.next_u64() as u128
    }

    /// Uniform `u64` in `[0, bound)` via rejection sampling (unbiased).
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        let zone = u64::MAX - (u64::MAX % bound);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % bound;
            }
        }
    }

    /// Encrypt `N` consecutive counter blocks into `out` (exactly
    /// `2·N` words) in one packed sweep, preserving the per-block
    /// `[y, x]` order of the scalar stream.
    #[inline]
    fn fill_blocks<const N: usize>(&mut self, out: &mut [u64]) {
        let mut xs = [0u64; N];
        let mut ys = [0u64; N];
        for lane in 0..N {
            xs[lane] = self.counter as u64;
            ys[lane] = (self.counter >> 64) as u64;
            self.counter = self.counter.wrapping_add(1);
        }
        self.cipher.encrypt_blocks(&mut xs, &mut ys);
        for lane in 0..N {
            out[2 * lane] = ys[lane];
            out[2 * lane + 1] = xs[lane];
        }
    }

    /// Fill a slice with uniform ring elements. This is the hot path for
    /// share expansion — it bypasses the single-lane buffer and encrypts
    /// whole counter blocks directly into the output, batching
    /// [`crate::runtime::simd::global_lanes`] independent blocks per
    /// Speck round sweep (the single-block ARX chain is latency-bound;
    /// the batch breaks it). The emitted stream is bit-identical to
    /// repeated [`Self::next_u64`] calls at every lane width.
    pub fn fill_u64s(&mut self, out: &mut [u64]) {
        let mut i = 0;
        // Drain buffered lanes first so the stream is identical to
        // repeated next_u64() calls.
        while i < out.len() && self.avail > 0 {
            self.avail -= 1;
            out[i] = self.buf[self.avail];
            i += 1;
        }
        // Packed counter-mode batches: `lanes` blocks per sweep.
        match crate::runtime::simd::global_lanes() {
            8 => {
                while i + 16 <= out.len() {
                    self.fill_blocks::<8>(&mut out[i..i + 16]);
                    i += 16;
                }
            }
            4 => {
                while i + 8 <= out.len() {
                    self.fill_blocks::<4>(&mut out[i..i + 8]);
                    i += 8;
                }
            }
            _ => {}
        }
        while i + 2 <= out.len() {
            let mut x = self.counter as u64;
            let mut y = (self.counter >> 64) as u64;
            self.counter = self.counter.wrapping_add(1);
            self.cipher.encrypt_words(&mut x, &mut y);
            // Match refill()+pop order: buf[1] is popped first.
            out[i] = y;
            out[i + 1] = x;
            i += 2;
        }
        while i < out.len() {
            out[i] = self.next_u64();
            i += 1;
        }
    }

    /// A fresh vector of uniform ring elements.
    pub fn u64s(&mut self, n: usize) -> Vec<u64> {
        let mut v = vec![0u64; n];
        self.fill_u64s(&mut v);
        v
    }

    /// Fill a byte slice.
    pub fn fill_bytes(&mut self, out: &mut [u8]) {
        let words = (out.len() + 7) / 8;
        let mut tmp = vec![0u64; words];
        self.fill_u64s(&mut tmp);
        for (i, b) in out.iter_mut().enumerate() {
            *b = (tmp[i / 8] >> (8 * (i % 8))) as u8;
        }
    }

    /// Uniform f64 in [0,1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box-Muller (data generators only).
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            let u2 = self.next_f64();
            if u1 > 1e-300 {
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Prg::new(42);
        let mut b = Prg::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Prg::new(1);
        let mut b = Prg::new(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn fill_matches_single_lane_stream() {
        let mut a = Prg::new(7);
        let mut b = Prg::new(7);
        // Misalign the buffer first.
        assert_eq!(a.next_u64(), b.next_u64());
        let mut bulk = vec![0u64; 33];
        a.fill_u64s(&mut bulk);
        for x in &bulk {
            assert_eq!(*x, b.next_u64());
        }
    }

    #[test]
    fn packed_fill_matches_scalar_stream_at_every_width() {
        use crate::runtime::simd::set_global_lanes;
        // Odd lengths + a misaligned buffer hit every path: buffer
        // drain, packed batches, leftover pair loop, odd tail.
        for len in [0usize, 1, 2, 3, 15, 16, 17, 31, 32, 33, 64, 129] {
            for misalign in [0usize, 1] {
                let mut want = vec![0u64; len];
                set_global_lanes(1);
                let mut p = Prg::new(0xF1F1);
                for _ in 0..misalign {
                    p.next_u64();
                }
                p.fill_u64s(&mut want);
                for width in [4usize, 8] {
                    set_global_lanes(width);
                    let mut q = Prg::new(0xF1F1);
                    for _ in 0..misalign {
                        q.next_u64();
                    }
                    let mut got = vec![0u64; len];
                    q.fill_u64s(&mut got);
                    assert_eq!(got, want, "len={len} misalign={misalign} width={width}");
                    // Post-fill state must agree too: the next draws
                    // continue the same stream.
                    set_global_lanes(1);
                    let mut pp = p.clone();
                    assert_eq!(q.next_u64(), pp.next_u64(), "state after len={len}");
                }
                set_global_lanes(1);
            }
        }
    }

    #[test]
    fn skip_to_matches_replayed_draws() {
        use crate::runtime::simd::set_global_lanes;
        // Every parity and every draw path (scalar, bulk fill) must land
        // on a position that skip_to reproduces exactly.
        set_global_lanes(1);
        for drawn in [0u64, 1, 2, 3, 7, 8, 33, 100] {
            let mut a = Prg::new(0xCAFE);
            for _ in 0..drawn {
                a.next_u64();
            }
            assert_eq!(a.position(), drawn);
            let mut b = Prg::new(0xCAFE);
            b.skip_to(drawn);
            assert_eq!(b.position(), drawn);
            for _ in 0..16 {
                assert_eq!(a.next_u64(), b.next_u64(), "drawn={drawn}");
            }
        }
        // fill_u64s advances position by exactly the slice length.
        let mut p = Prg::new(0xD00D);
        p.next_u64();
        let mut v = vec![0u64; 37];
        p.fill_u64s(&mut v);
        assert_eq!(p.position(), 38);
        let mut q = Prg::new(0xD00D);
        q.skip_to(38);
        assert_eq!(p.next_u64(), q.next_u64());
    }

    #[test]
    fn fork_is_independent() {
        let mut p = Prg::new(3);
        let mut c1 = p.fork(1);
        let mut c2 = p.fork(1); // same label, later state -> different seed
        assert_ne!(c1.next_u64(), c2.next_u64());
    }

    #[test]
    fn next_below_is_in_range_and_hits_all_small_values() {
        let mut p = Prg::new(11);
        let mut seen = [false; 5];
        for _ in 0..500 {
            let v = p.next_below(5);
            assert!(v < 5);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gaussian_moments_are_sane() {
        let mut p = Prg::new(13);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| p.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.08, "var {var}");
    }
}
