//! Library-wide error type.

use thiserror::Error;

/// Errors surfaced by the ppkmeans library.
#[derive(Error, Debug)]
pub enum Error {
    /// A transport endpoint closed while a protocol was mid-flight.
    #[error("transport channel closed: {0}")]
    ChannelClosed(String),

    /// Mismatched matrix / vector dimensions inside a protocol step.
    #[error("shape mismatch: {0}")]
    Shape(String),

    /// Offline material (triples, OTs) exhausted or of the wrong shape.
    #[error("offline store: {0}")]
    Offline(String),

    /// Homomorphic-encryption level failure (keygen, decrypt domain...).
    #[error("he: {0}")]
    He(String),

    /// Garbled-circuit garbling/evaluation failure.
    #[error("garbled circuit: {0}")]
    Gc(String),

    /// PJRT runtime failure (artifact missing, compile error, ...).
    #[error("runtime: {0}")]
    Runtime(String),

    /// Configuration / CLI error.
    #[error("config: {0}")]
    Config(String),

    /// Underlying XLA error.
    #[error("xla: {0}")]
    Xla(String),

    /// IO error (artifact files, datasets).
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
