//! Library-wide error type (hand-rolled; the crate builds offline with
//! no external dependencies).

/// Errors surfaced by the ppkmeans library.
#[derive(Debug)]
pub enum Error {
    /// A transport endpoint closed while a protocol was mid-flight.
    ChannelClosed(String),

    /// A peer violated the wire protocol: bad magic or version in the
    /// deployment handshake, a desynchronized phase barrier, an
    /// oversized or malformed frame. Unlike [`Error::ChannelClosed`]
    /// (the link died) this means the bytes that *did* arrive are not
    /// trustworthy.
    Protocol(String),

    /// Mismatched matrix / vector dimensions inside a protocol step.
    Shape(String),

    /// Offline material (triples, OTs) exhausted or of the wrong shape.
    Offline(String),

    /// Homomorphic-encryption level failure (keygen, decrypt domain...).
    He(String),

    /// Garbled-circuit garbling/evaluation failure.
    Gc(String),

    /// PJRT runtime failure (artifact missing, compile error, ...).
    Runtime(String),

    /// The scoring gateway refused or aborted a session under load:
    /// the admission queue is full or the material bank ran dry with
    /// replenishment disabled. Backpressure, not failure — the caller
    /// may retry once capacity frees up (see `serve::gateway`).
    Overload(String),

    /// A batched MAC / transcript-consistency check failed at a phase
    /// barrier under `Security::Malicious`: an opened value, a MAC limb
    /// or a wire frame did not verify. The message names the phase
    /// barrier that caught it. Unlike [`Error::Protocol`] this is an
    /// *integrity* verdict — the framing was fine, the contents lied.
    MacCheck(String),

    /// Configuration / CLI error.
    Config(String),

    /// Underlying XLA error.
    Xla(String),

    /// IO error (artifact files, datasets).
    Io(std::io::Error),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::ChannelClosed(s) => write!(f, "transport channel closed: {s}"),
            Error::Protocol(s) => write!(f, "wire protocol: {s}"),
            Error::Shape(s) => write!(f, "shape mismatch: {s}"),
            Error::Offline(s) => write!(f, "offline store: {s}"),
            Error::He(s) => write!(f, "he: {s}"),
            Error::Gc(s) => write!(f, "garbled circuit: {s}"),
            Error::Runtime(s) => write!(f, "runtime: {s}"),
            Error::Overload(s) => write!(f, "overload: {s}"),
            Error::MacCheck(s) => write!(f, "mac check failed: {s}"),
            Error::Config(s) => write!(f, "config: {s}"),
            Error::Xla(s) => write!(f, "xla: {s}"),
            Error::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

// The `pjrt` plumbing type-checks against the in-repo API stub
// (`runtime::xla_stub`), which is what CI's `cargo check --features
// pjrt` gate compiles; wiring a real XLA backend swaps the stub alias
// for the external `xla` crate (see the stub's module docs).
#[cfg(feature = "pjrt")]
impl From<crate::runtime::xla_stub::Error> for Error {
    fn from(e: crate::runtime::xla_stub::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_context() {
        let e = Error::Config("k must be >= 2".into());
        assert!(e.to_string().contains("config"));
        let io: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(io.to_string().contains("gone"));
    }
}
