//! Wall-clock timing helpers for the bench harness and phase metering.

use std::time::{Duration, Instant};

/// A simple start/stop accumulating timer.
#[derive(Debug, Default, Clone)]
pub struct Timer {
    total: Duration,
    started: Option<Instant>,
}

impl Timer {
    /// A stopped timer with zero accumulated time.
    pub fn new() -> Self {
        Self::default()
    }

    /// A timer that is already running — the `let t0 = Timer::started()`
    /// idiom replacing raw `Instant::now()` at telemetry sites, so the
    /// `Instant` type stays confined to this module.
    pub fn started() -> Self {
        let mut t = Self::new();
        t.start();
        t
    }

    /// Begin a timing interval (must not already be running).
    pub fn start(&mut self) {
        debug_assert!(self.started.is_none(), "timer already running");
        self.started = Some(Instant::now());
    }

    /// End the current interval, adding it to the accumulated total
    /// (no-op when stopped).
    pub fn stop(&mut self) {
        if let Some(s) = self.started.take() {
            self.total += s.elapsed();
        }
    }

    /// Accumulated time, including the in-flight interval if running.
    pub fn elapsed(&self) -> Duration {
        match self.started {
            Some(s) => self.total + s.elapsed(),
            None => self.total,
        }
    }

    /// [`Self::elapsed`] in fractional seconds.
    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_across_start_stop() {
        let mut t = Timer::new();
        t.start();
        std::thread::sleep(Duration::from_millis(5));
        t.stop();
        let a = t.secs();
        t.start();
        std::thread::sleep(Duration::from_millis(5));
        t.stop();
        assert!(t.secs() > a);
    }

    #[test]
    fn timed_returns_value() {
        let (v, s) = timed(|| 42);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }
}
