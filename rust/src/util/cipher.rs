//! Speck-128/128 block cipher (Beaulieu et al., 2013).
//!
//! Stand-in for fixed-key AES so the crate builds with no external
//! crates in an offline container: the PRG ([`crate::util::prng`]) runs
//! it in counter mode and the garbled-circuit hash
//! ([`crate::gc::garble`]) uses it as the fixed-key permutation of the
//! correlation-robust hash. Speck is a 32-round ARX design — three
//! operations per round, no tables — which keeps the implementation
//! auditable and the key schedule trivial. (For a production deployment
//! swap this module for hardware AES; every caller goes through the two
//! functions below.)

/// Expanded 32-round key schedule for a 128-bit key.
#[derive(Clone)]
pub struct Speck128 {
    ks: [u64; 32],
}

const ROUNDS: usize = 32;

#[inline(always)]
fn round(x: &mut u64, y: &mut u64, k: u64) {
    *x = x.rotate_right(8).wrapping_add(*y) ^ k;
    *y = y.rotate_left(3) ^ *x;
}

impl Speck128 {
    /// Expand a 16-byte key (little-endian word order).
    pub fn new(key: [u8; 16]) -> Speck128 {
        let mut k = u64::from_le_bytes(key[0..8].try_into().unwrap());
        let mut l = u64::from_le_bytes(key[8..16].try_into().unwrap());
        let mut ks = [0u64; 32];
        for (i, slot) in ks.iter_mut().enumerate() {
            *slot = k;
            // Key schedule reuses the round function with the counter as key.
            round(&mut l, &mut k, i as u64);
        }
        Speck128 { ks }
    }

    /// Encrypt one block given as two 64-bit words in place.
    #[inline]
    pub fn encrypt_words(&self, x: &mut u64, y: &mut u64) {
        for r in 0..ROUNDS {
            round(x, y, self.ks[r]);
        }
    }

    /// Encrypt a 128-bit value (little-endian word split).
    #[inline]
    pub fn encrypt_u128(&self, v: u128) -> u128 {
        let mut x = v as u64;
        let mut y = (v >> 64) as u64;
        self.encrypt_words(&mut x, &mut y);
        (x as u128) | ((y as u128) << 64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_key_dependent() {
        let a = Speck128::new([1; 16]);
        let b = Speck128::new([1; 16]);
        let c = Speck128::new([2; 16]);
        assert_eq!(a.encrypt_u128(42), b.encrypt_u128(42));
        assert_ne!(a.encrypt_u128(42), c.encrypt_u128(42));
    }

    #[test]
    fn nearby_inputs_diverge() {
        let k = Speck128::new(*b"ppkmeans-testkey");
        let e0 = k.encrypt_u128(0);
        let e1 = k.encrypt_u128(1);
        assert_ne!(e0, e1);
        // Crude avalanche check: a 1-bit input flip changes many bits.
        let flipped = (e0 ^ e1).count_ones();
        assert!(flipped > 30, "avalanche too weak: {flipped} bits");
    }

    #[test]
    fn counter_stream_has_no_short_cycle() {
        let k = Speck128::new([7; 16]);
        let mut seen = std::collections::HashSet::new();
        for i in 0..1000u128 {
            assert!(seen.insert(k.encrypt_u128(i)), "collision at {i}");
        }
    }
}
