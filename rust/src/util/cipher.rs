//! Speck-128/128 block cipher (Beaulieu et al., 2013).
//!
//! Stand-in for fixed-key AES so the crate builds with no external
//! crates in an offline container: the PRG ([`crate::util::prng`]) runs
//! it in counter mode and the garbled-circuit hash
//! ([`crate::gc::garble`]) uses it as the fixed-key permutation of the
//! correlation-robust hash. Speck is a 32-round ARX design — three
//! operations per round, no tables — which keeps the implementation
//! auditable and the key schedule trivial. (For a production deployment
//! swap this module for hardware AES; every caller goes through the two
//! functions below.)

use crate::runtime::simd::U64s;

/// Expanded 32-round key schedule for a 128-bit key.
#[derive(Clone)]
pub struct Speck128 {
    ks: [u64; 32],
}

const ROUNDS: usize = 32;

#[inline(always)]
fn round(x: &mut u64, y: &mut u64, k: u64) {
    *x = x.rotate_right(8).wrapping_add(*y) ^ k;
    *y = y.rotate_left(3) ^ *x;
}

impl Speck128 {
    /// Expand a 16-byte key (little-endian word order).
    pub fn new(key: [u8; 16]) -> Speck128 {
        let mut k = u64::from_le_bytes(key[0..8].try_into().unwrap());
        let mut l = u64::from_le_bytes(key[8..16].try_into().unwrap());
        let mut ks = [0u64; 32];
        for (i, slot) in ks.iter_mut().enumerate() {
            *slot = k;
            // Key schedule reuses the round function with the counter as key.
            round(&mut l, &mut k, i as u64);
        }
        Speck128 { ks }
    }

    /// Encrypt one block given as two 64-bit words in place.
    #[inline]
    pub fn encrypt_words(&self, x: &mut u64, y: &mut u64) {
        for r in 0..ROUNDS {
            round(x, y, self.ks[r]);
        }
    }

    /// Encrypt a 128-bit value (little-endian word split).
    #[inline]
    pub fn encrypt_u128(&self, v: u128) -> u128 {
        let mut x = v as u64;
        let mut y = (v >> 64) as u64;
        self.encrypt_words(&mut x, &mut y);
        (x as u128) | ((y as u128) << 64)
    }

    /// Encrypt `N` independent blocks in one packed round sweep.
    ///
    /// The single-block ARX chain is latency-bound (three dependent ops
    /// per round); `N` independent blocks break the chain, so each round
    /// becomes a lanewise [`U64s`] sweep the compiler autovectorizes —
    /// the counter-mode hot path of [`crate::util::prng::Prg`] bulk
    /// draws. Bit-identical to `N` [`Self::encrypt_words`] calls.
    #[inline]
    pub fn encrypt_blocks<const N: usize>(&self, xs: &mut [u64; N], ys: &mut [u64; N]) {
        let mut x = U64s(*xs);
        let mut y = U64s(*ys);
        for r in 0..ROUNDS {
            let k = U64s::<N>::splat(self.ks[r]);
            x = x.rotr(8).add(y).xor(k);
            y = y.rotl(3).xor(x);
        }
        *xs = x.0;
        *ys = y.0;
    }
}

/// `N` Speck-128/128 instances with *distinct* keys, key-scheduled and
/// run in lockstep — the engine behind
/// [`crate::util::hash::hash256_many`], where every 16-byte message
/// block is a fresh cipher key (Davies–Meyer). Both the key schedule
/// and encryption are lanewise [`U64s`] sweeps; lane `i` is
/// bit-identical to a scalar `Speck128::new(keys[i])`.
pub struct SpeckMulti<const N: usize> {
    ks: [[u64; N]; 32],
}

impl<const N: usize> SpeckMulti<N> {
    /// Expand `N` 16-byte keys in one packed sweep.
    pub fn new(keys: &[[u8; 16]; N]) -> SpeckMulti<N> {
        let mut k = [0u64; N];
        let mut l = [0u64; N];
        for lane in 0..N {
            k[lane] = u64::from_le_bytes(keys[lane][0..8].try_into().unwrap());
            l[lane] = u64::from_le_bytes(keys[lane][8..16].try_into().unwrap());
        }
        let mut ks = [[0u64; N]; 32];
        for (i, slot) in ks.iter_mut().enumerate() {
            *slot = k;
            // Same schedule as the scalar path: one round with the
            // counter as key, applied to every lane.
            let c = U64s::<N>::splat(i as u64);
            let mut x = U64s(l);
            let mut y = U64s(k);
            x = x.rotr(8).add(y).xor(c);
            y = y.rotl(3).xor(x);
            l = x.0;
            k = y.0;
        }
        SpeckMulti { ks }
    }

    /// Encrypt one 128-bit value per lane (lane `i` under key `i`).
    #[inline]
    pub fn encrypt_u128s(&self, vs: &[u128; N]) -> [u128; N] {
        let mut xs = [0u64; N];
        let mut ys = [0u64; N];
        for lane in 0..N {
            xs[lane] = vs[lane] as u64;
            ys[lane] = (vs[lane] >> 64) as u64;
        }
        let mut x = U64s(xs);
        let mut y = U64s(ys);
        for r in 0..ROUNDS {
            let k = U64s(self.ks[r]);
            x = x.rotr(8).add(y).xor(k);
            y = y.rotl(3).xor(x);
        }
        let mut out = [0u128; N];
        for lane in 0..N {
            out[lane] = (x.0[lane] as u128) | ((y.0[lane] as u128) << 64);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_key_dependent() {
        let a = Speck128::new([1; 16]);
        let b = Speck128::new([1; 16]);
        let c = Speck128::new([2; 16]);
        assert_eq!(a.encrypt_u128(42), b.encrypt_u128(42));
        assert_ne!(a.encrypt_u128(42), c.encrypt_u128(42));
    }

    #[test]
    fn nearby_inputs_diverge() {
        let k = Speck128::new(*b"ppkmeans-testkey");
        let e0 = k.encrypt_u128(0);
        let e1 = k.encrypt_u128(1);
        assert_ne!(e0, e1);
        // Crude avalanche check: a 1-bit input flip changes many bits.
        let flipped = (e0 ^ e1).count_ones();
        assert!(flipped > 30, "avalanche too weak: {flipped} bits");
    }

    #[test]
    fn counter_stream_has_no_short_cycle() {
        let k = Speck128::new([7; 16]);
        let mut seen = std::collections::HashSet::new();
        for i in 0..1000u128 {
            assert!(seen.insert(k.encrypt_u128(i)), "collision at {i}");
        }
    }

    #[test]
    fn packed_blocks_match_scalar_encryption() {
        let k = Speck128::new(*b"ppkmeans-simdkey");
        let mut xs: [u64; 8] = std::array::from_fn(|i| 0x1111 * i as u64);
        let mut ys: [u64; 8] = std::array::from_fn(|i| !(0x7 * i as u64));
        let (xs0, ys0) = (xs, ys);
        k.encrypt_blocks(&mut xs, &mut ys);
        for i in 0..8 {
            let (mut x, mut y) = (xs0[i], ys0[i]);
            k.encrypt_words(&mut x, &mut y);
            assert_eq!((xs[i], ys[i]), (x, y), "lane {i}");
        }
        // 4-lane width too.
        let mut x4 = [1u64, 2, 3, 4];
        let mut y4 = [5u64, 6, 7, 8];
        k.encrypt_blocks(&mut x4, &mut y4);
        let (mut x, mut y) = (3u64, 7u64);
        k.encrypt_words(&mut x, &mut y);
        assert_eq!((x4[2], y4[2]), (x, y));
    }

    #[test]
    fn multi_key_lanes_match_scalar_instances() {
        let keys: [[u8; 16]; 4] = std::array::from_fn(|i| [i as u8 + 1; 16]);
        let multi = SpeckMulti::new(&keys);
        let vs: [u128; 4] = [42, u128::MAX, 7 << 90, 0];
        let got = multi.encrypt_u128s(&vs);
        for i in 0..4 {
            assert_eq!(got[i], Speck128::new(keys[i]).encrypt_u128(vs[i]), "lane {i}");
        }
    }
}
