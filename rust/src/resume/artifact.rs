//! The `PPKMCKP1` checkpoint artifact.
//!
//! A [`Checkpoint`] is one party's complete protocol state at a named
//! pipeline site (a Lloyd-iteration boundary, the `train.done` barrier,
//! or a scored serve batch), framed with the same discipline as the
//! model artifact (`PPKMDL01`, [`crate::serve::model`]): an 8-byte
//! magic, a `u32` version, fixed-width little-endian fields via
//! [`crate::util::codec`], and a trailing FNV-1a checksum over every
//! preceding byte. Parsing validates in a fixed order — length, magic,
//! checksum, version, field ranges — and every header-derived length is
//! bounds-checked against the remaining input *before* any allocation,
//! so a truncated or forged file is a typed [`Error::Config`] naming
//! the defect, never a panic or a huge reservation.
//!
//! The byte layout is documented in `docs/PROTOCOLS.md` ("Crash
//! resumability" appendix).

// Checkpoint files are untrusted input on the resume path: typed
// errors only (ppkm-lint rule no-panic-in-wire-paths covers resume/).
#![deny(clippy::unwrap_used, clippy::expect_used)]

use crate::net::meter::PhaseStats;
use crate::offline::store::Demand;
use crate::ring::matrix::Mat;
use crate::serve::scorer::ScoreResult;
use crate::ss::triples::Ledger;
use crate::util::codec::{fnv1a64, push_str, push_u32, push_u64};
use crate::util::error::{Error, Result};
use crate::util::hash::Hash256;
use std::path::{Path, PathBuf};

/// Artifact magic: the ASCII bytes `PPKMCKP1`.
pub const CKPT_MAGIC: [u8; 8] = *b"PPKMCKP1";
/// Checkpoint format version this build reads and writes.
pub const CKPT_VERSION: u32 = 1;

const WHAT: &str = "checkpoint artifact";

fn bad(msg: impl Into<String>) -> Error {
    Error::Config(format!("{WHAT}: {}", msg.into()))
}

fn rd_u32(b: &[u8], off: &mut usize) -> Result<u32> {
    crate::util::codec::rd_u32(b, off, WHAT)
}

fn rd_u64(b: &[u8], off: &mut usize) -> Result<u64> {
    crate::util::codec::rd_u64(b, off, WHAT)
}

fn rd_str(b: &[u8], off: &mut usize) -> Result<String> {
    crate::util::codec::rd_str(b, off, WHAT)
}

fn rd_bytes(b: &[u8], off: &mut usize) -> Result<Vec<u8>> {
    crate::util::codec::rd_bytes(b, off, WHAT)
}

/// A serialized [`crate::net::Meter`] snapshot: per-phase stats (sorted
/// by phase label), the current phase label, and the flight-open flag.
pub type MeterSnapshot = (Vec<(String, PhaseStats)>, String, bool);

/// Replenished-bank counters frozen at a serve checkpoint; the bank is
/// rebuilt on resume by replaying the exact historical fabrication
/// sequence these counters describe
/// (see `MaterialBank::restore`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct BankCounters {
    /// Batches fabricated up front.
    pub prefabricated: u64,
    /// Batches added by replenishment.
    pub replenished: u64,
    /// Batches checked out so far.
    pub consumed: u64,
    /// Replenishment events so far.
    pub replenish_events: u64,
    /// Checkouts that replenished synchronously on the scoring path.
    pub stalls: u64,
}

/// Mid-training state at a Lloyd-iteration boundary (`train.iter.{i}`).
#[derive(Debug, Clone, PartialEq)]
pub struct TrainState {
    /// Iterations fully completed (1-based count, = the loop's `iters`).
    pub iter: u32,
    /// Whether the convergence check already decided to stop.
    pub stop: bool,
    /// This party's current centroid share (k×d).
    pub mu: Mat,
    /// This party's current one-hot assignment share (n×k).
    pub c_share: Mat,
    /// The dealer PRG stream position ([`crate::util::prng::Prg::position`]).
    pub dealer_pos: u64,
    /// Offline material consumed so far.
    pub ledger: Ledger,
    /// Total offline demand recorded so far.
    pub demand: Demand,
    /// Demand attributed to each step (S1, S2, S3) so far.
    pub step_demands: [Demand; 3],
}

/// State at the `train.done` barrier: the finished model share, opaque
/// bytes so this module never depends on the serving layer's types.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainDoneState {
    /// `TrainedModel::to_bytes` of this party's share.
    pub model: Vec<u8>,
}

/// Mid-serving state after a scored batch (`serve.batch.{i}`).
#[derive(Debug, Clone, PartialEq)]
pub struct ServeState {
    /// `TrainedModel::to_bytes` of this party's **current** share —
    /// includes any centroid-refresh deltas applied so far.
    pub model: Vec<u8>,
    /// The scorer's cached shared norm row (1×k, scale 2f).
    pub u_row: Mat,
    /// Centroid refreshes applied so far (keys the refresh dealer seed).
    pub refreshes_done: u32,
    /// Batches fully scored (the next batch index to run).
    pub batches_scored: u32,
    /// The probe batch's recorded per-batch demand the bank plans from.
    pub per_batch: Demand,
    /// Bank ledger counters at the checkpoint.
    pub bank: BankCounters,
    /// Traffic of the one-time scorer warmup.
    pub warmup: PhaseStats,
    /// Revealed results of every scored batch so far.
    pub results: Vec<ScoreResult>,
    /// Per-batch `(rows, flagged, online)` stats so far. Wall-clock is
    /// deliberately **not** persisted (transcripts exclude it); resumed
    /// batches report `wall_secs = 0`.
    pub stats: Vec<(u64, u64, PhaseStats)>,
}

/// The pipeline-specific state a checkpoint snapshots.
#[derive(Debug, Clone, PartialEq)]
pub enum Payload {
    /// A Lloyd-iteration boundary.
    Train(TrainState),
    /// The `train.done` barrier.
    TrainDone(TrainDoneState),
    /// A scored serve batch.
    Serve(ServeState),
}

impl Payload {
    fn tag(&self) -> u32 {
        match self {
            Payload::Train(_) => 1,
            Payload::TrainDone(_) => 2,
            Payload::Serve(_) => 3,
        }
    }
}

/// One party's versioned, checksummed protocol snapshot at a named
/// pipeline site. `party{p}.{ordinal:05}.ppkmckp` files accumulate in
/// the checkpoint directory — one per site, every site kept — and the
/// resume leg of the handshake negotiates the highest ordinal both
/// parties hold.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    /// Owning party (0 or 1).
    pub party: usize,
    /// Position in the pipeline's checkpoint sequence (1-based; 0 is
    /// reserved on the wire for "no checkpoint").
    pub ordinal: u32,
    /// The site label (`train.iter.{i}` / `train.done` / `serve.batch.{i}`).
    pub label: String,
    /// Digest of the canonical scenario this state belongs to.
    pub scenario: [u8; 32],
    /// Transcript reveals accumulated before this site.
    pub reveals: Vec<(String, String)>,
    /// The channel meter at this site.
    pub meter: MeterSnapshot,
    /// Pipeline-specific state.
    pub payload: Payload,
}

// ---- field codecs --------------------------------------------------------

fn push_mat(out: &mut Vec<u8>, m: &Mat) {
    push_u32(out, m.rows as u32);
    push_u32(out, m.cols as u32);
    for &w in &m.data {
        push_u64(out, w);
    }
}

fn rd_mat(b: &[u8], off: &mut usize) -> Result<Mat> {
    let rows = rd_u32(b, off)? as usize;
    let cols = rd_u32(b, off)? as usize;
    let elems = rows.checked_mul(cols).ok_or_else(|| bad("matrix shape overflows"))?;
    let need = elems.checked_mul(8).ok_or_else(|| bad("matrix shape overflows"))?;
    let end = off
        .checked_add(need)
        .filter(|&e| e <= b.len())
        .ok_or_else(|| bad("truncated matrix body"))?;
    let mut data = Vec::with_capacity(elems);
    for chunk in b[*off..end].chunks_exact(8) {
        let mut w = [0u8; 8];
        w.copy_from_slice(chunk);
        data.push(u64::from_le_bytes(w));
    }
    *off = end;
    Ok(Mat { rows, cols, data })
}

fn push_stats(out: &mut Vec<u8>, p: &PhaseStats) {
    push_u64(out, p.bytes_sent);
    push_u64(out, p.msgs_sent);
    push_u64(out, p.rounds);
}

fn rd_stats(b: &[u8], off: &mut usize) -> Result<PhaseStats> {
    Ok(PhaseStats {
        bytes_sent: rd_u64(b, off)?,
        msgs_sent: rd_u64(b, off)?,
        rounds: rd_u64(b, off)?,
    })
}

fn push_ledger(out: &mut Vec<u8>, l: &Ledger) {
    push_u64(out, l.mat_triple_elems);
    push_u64(out, l.mat_triples);
    push_u64(out, l.vec_triple_lanes);
    push_u64(out, l.bit_triple_lanes);
    push_u64(out, l.dabit_lanes);
}

fn rd_ledger(b: &[u8], off: &mut usize) -> Result<Ledger> {
    Ok(Ledger {
        mat_triple_elems: rd_u64(b, off)?,
        mat_triples: rd_u64(b, off)?,
        vec_triple_lanes: rd_u64(b, off)?,
        bit_triple_lanes: rd_u64(b, off)?,
        dabit_lanes: rd_u64(b, off)?,
    })
}

fn push_demand(out: &mut Vec<u8>, d: &Demand) {
    push_u32(out, d.mats.len() as u32);
    for &((m, k, n), count) in &d.mats {
        push_u64(out, m as u64);
        push_u64(out, k as u64);
        push_u64(out, n as u64);
        push_u64(out, count as u64);
    }
    for chunks in [&d.vec_chunks, &d.bit_chunks, &d.dabit_chunks] {
        push_u32(out, chunks.len() as u32);
        for &c in chunks {
            push_u64(out, c as u64);
        }
    }
}

fn rd_demand(b: &[u8], off: &mut usize) -> Result<Demand> {
    let nmats = rd_u32(b, off)? as usize;
    // Four u64s per entry: refuse a forged count before reserving.
    if off.checked_add(nmats.saturating_mul(32)).filter(|&e| e <= b.len()).is_none() {
        return Err(bad("truncated demand table"));
    }
    let mut d = Demand::default();
    d.mats.reserve(nmats);
    for _ in 0..nmats {
        let m = rd_u64(b, off)? as usize;
        let k = rd_u64(b, off)? as usize;
        let n = rd_u64(b, off)? as usize;
        let count = rd_u64(b, off)? as usize;
        d.mats.push(((m, k, n), count));
    }
    for chunks in [&mut d.vec_chunks, &mut d.bit_chunks, &mut d.dabit_chunks] {
        let len = rd_u32(b, off)? as usize;
        if off.checked_add(len.saturating_mul(8)).filter(|&e| e <= b.len()).is_none() {
            return Err(bad("truncated demand chunks"));
        }
        chunks.reserve(len);
        for _ in 0..len {
            chunks.push(rd_u64(b, off)? as usize);
        }
    }
    Ok(d)
}

fn push_result(out: &mut Vec<u8>, r: &ScoreResult) {
    push_u32(out, r.assignments.len() as u32);
    for &a in &r.assignments {
        push_u64(out, a as u64);
    }
    for &f in &r.fraud_flags {
        out.push(f as u8);
    }
    push_u64(out, r.malformed_rows as u64);
}

fn rd_result(b: &[u8], off: &mut usize) -> Result<ScoreResult> {
    let rows = rd_u32(b, off)? as usize;
    // rows×8 assignment words + rows flag bytes, checked up front.
    if off.checked_add(rows.saturating_mul(9)).filter(|&e| e <= b.len()).is_none() {
        return Err(bad("truncated batch result"));
    }
    let mut assignments = Vec::with_capacity(rows);
    for _ in 0..rows {
        assignments.push(rd_u64(b, off)? as usize);
    }
    let mut fraud_flags = Vec::with_capacity(rows);
    for _ in 0..rows {
        let end = *off + 1;
        fraud_flags.push(b[*off] != 0);
        *off = end;
    }
    let malformed_rows = rd_u64(b, off)? as usize;
    Ok(ScoreResult { assignments, fraud_flags, malformed_rows })
}

// ---- the artifact --------------------------------------------------------

impl Checkpoint {
    /// Conventional file name inside a checkpoint directory.
    pub fn file_name(party: usize, ordinal: u32) -> String {
        format!("party{party}.{ordinal:05}.ppkmckp")
    }

    /// A digest binding `(scenario, ordinal, label)` — what the resume
    /// leg of the handshake exchanges to confirm both parties hold the
    /// *same* checkpoint before replaying from it.
    pub fn confirm_digest(&self) -> [u8; 32] {
        confirm_digest(&self.scenario, self.ordinal, &self.label)
    }

    /// Typed check that this checkpoint belongs to `digest`'s scenario.
    pub fn verify_scenario(&self, digest: &[u8; 32]) -> Result<()> {
        if self.scenario != *digest {
            return Err(bad(format!(
                "scenario digest mismatch — checkpoint {:?} (ordinal {}) was written by a \
                 different scenario",
                self.label, self.ordinal
            )));
        }
        Ok(())
    }

    /// Serialize to the `PPKMCKP1` byte format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(&CKPT_MAGIC);
        push_u32(&mut out, CKPT_VERSION);
        push_u32(&mut out, self.party as u32);
        push_u32(&mut out, self.ordinal);
        push_str(&mut out, &self.label);
        out.extend_from_slice(&self.scenario);
        push_u32(&mut out, self.reveals.len() as u32);
        for (k, v) in &self.reveals {
            push_str(&mut out, k);
            push_str(&mut out, v);
        }
        let (phases, current, flight_open) = &self.meter;
        push_u32(&mut out, phases.len() as u32);
        for (label, stats) in phases {
            push_str(&mut out, label);
            push_stats(&mut out, stats);
        }
        push_str(&mut out, current);
        push_u32(&mut out, *flight_open as u32);
        push_u32(&mut out, self.payload.tag());
        match &self.payload {
            Payload::Train(t) => {
                push_u32(&mut out, t.iter);
                push_u32(&mut out, t.stop as u32);
                push_mat(&mut out, &t.mu);
                push_mat(&mut out, &t.c_share);
                push_u64(&mut out, t.dealer_pos);
                push_ledger(&mut out, &t.ledger);
                push_demand(&mut out, &t.demand);
                for d in &t.step_demands {
                    push_demand(&mut out, d);
                }
            }
            Payload::TrainDone(t) => {
                crate::util::codec::push_bytes(&mut out, &t.model);
            }
            Payload::Serve(s) => {
                crate::util::codec::push_bytes(&mut out, &s.model);
                push_mat(&mut out, &s.u_row);
                push_u32(&mut out, s.refreshes_done);
                push_u32(&mut out, s.batches_scored);
                push_demand(&mut out, &s.per_batch);
                push_u64(&mut out, s.bank.prefabricated);
                push_u64(&mut out, s.bank.replenished);
                push_u64(&mut out, s.bank.consumed);
                push_u64(&mut out, s.bank.replenish_events);
                push_u64(&mut out, s.bank.stalls);
                push_stats(&mut out, &s.warmup);
                push_u32(&mut out, s.results.len() as u32);
                for r in &s.results {
                    push_result(&mut out, r);
                }
                push_u32(&mut out, s.stats.len() as u32);
                for (rows, flagged, online) in &s.stats {
                    push_u64(&mut out, *rows);
                    push_u64(&mut out, *flagged);
                    push_stats(&mut out, online);
                }
            }
        }
        let checksum = fnv1a64(&out);
        push_u64(&mut out, checksum);
        out
    }

    /// Parse and validate the `PPKMCKP1` byte format. Validation order:
    /// length, magic, checksum, version, field ranges — with every
    /// header-derived length bounds-checked before allocation, and a
    /// final trailing-bytes check so appended garbage is refused.
    pub fn from_bytes(bytes: &[u8]) -> Result<Checkpoint> {
        if bytes.len() < 16 {
            return Err(bad(format!("{} bytes is too short to be a checkpoint", bytes.len())));
        }
        if bytes[..8] != CKPT_MAGIC {
            return Err(bad("bad magic (not a ppkmeans checkpoint)"));
        }
        let body_len = bytes.len() - 8;
        let mut w = [0u8; 8];
        w.copy_from_slice(&bytes[body_len..]);
        if fnv1a64(&bytes[..body_len]) != u64::from_le_bytes(w) {
            return Err(bad("checksum mismatch (corrupted file)"));
        }
        let b = &bytes[..body_len];
        let mut off = 8;
        let version = rd_u32(b, &mut off)?;
        if version != CKPT_VERSION {
            return Err(bad(format!(
                "unsupported version {version} (this build reads version {CKPT_VERSION})"
            )));
        }
        let party = rd_u32(b, &mut off)? as usize;
        if party > 1 {
            return Err(bad(format!("party {party} out of range (0|1)")));
        }
        let ordinal = rd_u32(b, &mut off)?;
        if ordinal == 0 {
            return Err(bad("ordinal 0 is reserved for \"no checkpoint\""));
        }
        let label = rd_str(b, &mut off)?;
        let end = off
            .checked_add(32)
            .filter(|&e| e <= b.len())
            .ok_or_else(|| bad("truncated scenario digest"))?;
        let mut scenario = [0u8; 32];
        scenario.copy_from_slice(&b[off..end]);
        off = end;
        let nreveals = rd_u32(b, &mut off)? as usize;
        if off.checked_add(nreveals.saturating_mul(8)).filter(|&e| e <= b.len()).is_none() {
            return Err(bad("truncated reveal table"));
        }
        let mut reveals = Vec::with_capacity(nreveals);
        for _ in 0..nreveals {
            let k = rd_str(b, &mut off)?;
            let v = rd_str(b, &mut off)?;
            reveals.push((k, v));
        }
        let nphases = rd_u32(b, &mut off)? as usize;
        if off.checked_add(nphases.saturating_mul(28)).filter(|&e| e <= b.len()).is_none() {
            return Err(bad("truncated meter table"));
        }
        let mut phases = Vec::with_capacity(nphases);
        for _ in 0..nphases {
            let l = rd_str(b, &mut off)?;
            let s = rd_stats(b, &mut off)?;
            phases.push((l, s));
        }
        let current = rd_str(b, &mut off)?;
        let flight_open = rd_u32(b, &mut off)? != 0;
        let payload = match rd_u32(b, &mut off)? {
            1 => {
                let iter = rd_u32(b, &mut off)?;
                let stop = rd_u32(b, &mut off)? != 0;
                let mu = rd_mat(b, &mut off)?;
                let c_share = rd_mat(b, &mut off)?;
                let dealer_pos = rd_u64(b, &mut off)?;
                let ledger = rd_ledger(b, &mut off)?;
                let demand = rd_demand(b, &mut off)?;
                let step_demands =
                    [rd_demand(b, &mut off)?, rd_demand(b, &mut off)?, rd_demand(b, &mut off)?];
                Payload::Train(TrainState {
                    iter,
                    stop,
                    mu,
                    c_share,
                    dealer_pos,
                    ledger,
                    demand,
                    step_demands,
                })
            }
            2 => Payload::TrainDone(TrainDoneState { model: rd_bytes(b, &mut off)? }),
            3 => {
                let model = rd_bytes(b, &mut off)?;
                let u_row = rd_mat(b, &mut off)?;
                let refreshes_done = rd_u32(b, &mut off)?;
                let batches_scored = rd_u32(b, &mut off)?;
                let per_batch = rd_demand(b, &mut off)?;
                let bank = BankCounters {
                    prefabricated: rd_u64(b, &mut off)?,
                    replenished: rd_u64(b, &mut off)?,
                    consumed: rd_u64(b, &mut off)?,
                    replenish_events: rd_u64(b, &mut off)?,
                    stalls: rd_u64(b, &mut off)?,
                };
                let warmup = rd_stats(b, &mut off)?;
                let nresults = rd_u32(b, &mut off)? as usize;
                if off.checked_add(nresults.saturating_mul(12)).filter(|&e| e <= b.len()).is_none()
                {
                    return Err(bad("truncated result table"));
                }
                let mut results = Vec::with_capacity(nresults);
                for _ in 0..nresults {
                    results.push(rd_result(b, &mut off)?);
                }
                let nstats = rd_u32(b, &mut off)? as usize;
                if off.checked_add(nstats.saturating_mul(40)).filter(|&e| e <= b.len()).is_none() {
                    return Err(bad("truncated batch-stats table"));
                }
                let mut stats = Vec::with_capacity(nstats);
                for _ in 0..nstats {
                    let rows = rd_u64(b, &mut off)?;
                    let flagged = rd_u64(b, &mut off)?;
                    let online = rd_stats(b, &mut off)?;
                    stats.push((rows, flagged, online));
                }
                Payload::Serve(ServeState {
                    model,
                    u_row,
                    refreshes_done,
                    batches_scored,
                    per_batch,
                    bank,
                    warmup,
                    results,
                    stats,
                })
            }
            other => return Err(bad(format!("unknown payload tag {other}"))),
        };
        if off != b.len() {
            return Err(bad(format!("{} trailing bytes after the payload", b.len() - off)));
        }
        Ok(Checkpoint { party, ordinal, label, scenario, reveals, meter: (phases, current, flight_open), payload })
    }

    /// Write atomically into `dir` (temp file + rename, so a crash
    /// mid-write never leaves a torn file under the canonical name).
    pub fn save(&self, dir: &Path) -> Result<PathBuf> {
        std::fs::create_dir_all(dir)?;
        let name = Checkpoint::file_name(self.party, self.ordinal);
        let path = dir.join(&name);
        let tmp = dir.join(format!("{name}.tmp"));
        std::fs::write(&tmp, self.to_bytes())?;
        std::fs::rename(&tmp, &path)?;
        Ok(path)
    }

    /// Load and validate a checkpoint file.
    pub fn load(path: &Path) -> Result<Checkpoint> {
        let bytes = std::fs::read(path)?;
        Checkpoint::from_bytes(&bytes)
    }
}

/// The `(scenario, ordinal, label)` binding digest (see
/// [`Checkpoint::confirm_digest`]); free function so the handshake can
/// also compute it for diagnostics.
pub fn confirm_digest(scenario: &[u8; 32], ordinal: u32, label: &str) -> [u8; 32] {
    let mut h = Hash256::new();
    h.update(*scenario);
    h.update(ordinal.to_le_bytes());
    h.update(label.as_bytes());
    h.finalize()
}

/// Scan `dir` for this party's highest usable checkpoint for the given
/// scenario: unparseable or corrupted files are skipped (a torn tail
/// from a crash must not wedge resume), and checkpoints from other
/// scenarios are filtered by digest. Returns 0 when none qualify.
pub fn scan_max_ordinal(dir: &Path, party: usize, scenario: &[u8; 32]) -> u32 {
    let Ok(entries) = std::fs::read_dir(dir) else { return 0 };
    let prefix = format!("party{party}.");
    let mut best = 0u32;
    for entry in entries.flatten() {
        let name = entry.file_name();
        let name = name.to_string_lossy().into_owned();
        if !name.starts_with(&prefix) || !name.ends_with(".ppkmckp") {
            continue;
        }
        let Ok(ckpt) = Checkpoint::load(&entry.path()) else { continue };
        if ckpt.party == party && ckpt.scenario == *scenario && ckpt.ordinal > best {
            best = ckpt.ordinal;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;

    fn sample(party: usize, ordinal: u32, payload: Payload) -> Checkpoint {
        Checkpoint {
            party,
            ordinal,
            label: "train.iter.1".into(),
            scenario: [7u8; 32],
            reveals: vec![("centroids".into(), "abc123".into())],
            meter: (
                vec![
                    ("handshake".into(), PhaseStats { bytes_sent: 72, msgs_sent: 1, rounds: 1 }),
                    ("online.s1".into(), PhaseStats { bytes_sent: 999, msgs_sent: 4, rounds: 2 }),
                ],
                "online.s1".into(),
                false,
            ),
            payload,
        }
    }

    fn train_payload() -> Payload {
        let mut demand = Demand::default();
        demand.mat(4, 2, 3);
        demand.vec_lanes(17);
        Payload::Train(TrainState {
            iter: 2,
            stop: false,
            mu: Mat::from_vec(2, 3, vec![1, 2, 3, 4, 5, 6]),
            c_share: Mat::from_vec(3, 2, vec![9, 8, 7, 6, 5, 4]),
            dealer_pos: 12345,
            ledger: Ledger {
                mat_triple_elems: 10,
                mat_triples: 2,
                vec_triple_lanes: 3,
                bit_triple_lanes: 4,
                dabit_lanes: 5,
            },
            demand: demand.clone(),
            step_demands: [demand.clone(), Demand::default(), demand],
        })
    }

    fn serve_payload() -> Payload {
        let mut per_batch = Demand::default();
        per_batch.mat(16, 2, 2);
        per_batch.dabit_lanes(32);
        Payload::Serve(ServeState {
            model: vec![1, 2, 3, 4, 5],
            u_row: Mat::from_vec(1, 2, vec![11, 22]),
            refreshes_done: 1,
            batches_scored: 2,
            per_batch,
            bank: BankCounters {
                prefabricated: 2,
                replenished: 2,
                consumed: 2,
                replenish_events: 1,
                stalls: 0,
            },
            warmup: PhaseStats { bytes_sent: 64, msgs_sent: 1, rounds: 1 },
            results: vec![ScoreResult {
                assignments: vec![0, 1, 1, 0],
                fraud_flags: vec![false, true, false, false],
                malformed_rows: 0,
            }],
            stats: vec![(4, 1, PhaseStats { bytes_sent: 100, msgs_sent: 3, rounds: 3 })],
        })
    }

    #[test]
    fn roundtrips_every_payload_kind() {
        for payload in [
            train_payload(),
            Payload::TrainDone(TrainDoneState { model: vec![0xAA; 40] }),
            serve_payload(),
        ] {
            let ckpt = sample(1, 3, payload);
            let back = Checkpoint::from_bytes(&ckpt.to_bytes()).unwrap();
            assert_eq!(back, ckpt);
        }
    }

    #[test]
    fn truncation_anywhere_is_a_typed_error() {
        let bytes = sample(0, 1, train_payload()).to_bytes();
        for cut in [0, 4, 15, bytes.len() / 2, bytes.len() - 1] {
            let err = Checkpoint::from_bytes(&bytes[..cut]).unwrap_err();
            assert!(err.to_string().contains("checkpoint artifact"), "cut {cut}: {err}");
        }
    }

    #[test]
    fn flipped_byte_fails_the_checksum() {
        let mut bytes = sample(0, 2, serve_payload()).to_bytes();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        let err = Checkpoint::from_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
    }

    #[test]
    fn wrong_magic_is_not_a_checkpoint() {
        let mut bytes = sample(0, 1, train_payload()).to_bytes();
        bytes[0] = b'X';
        let err = Checkpoint::from_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("magic"), "{err}");
    }

    #[test]
    fn version_skew_names_both_versions() {
        // Rebuild with a bumped version and a recomputed checksum, so
        // the version check (not the checksum) is what trips.
        let mut bytes = sample(0, 1, train_payload()).to_bytes();
        let body = bytes.len() - 8;
        bytes[8..12].copy_from_slice(&99u32.to_le_bytes());
        let sum = fnv1a64(&bytes[..body]);
        bytes[body..].copy_from_slice(&sum.to_le_bytes());
        let err = Checkpoint::from_bytes(&bytes).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("99") && msg.contains('1'), "{msg}");
    }

    #[test]
    fn trailing_garbage_is_refused() {
        let ckpt = sample(0, 1, train_payload());
        let mut bytes = ckpt.to_bytes();
        // Splice extra bytes before the checksum and recompute it, so
        // only the trailing-bytes check can catch the padding.
        let body = bytes.len() - 8;
        bytes.truncate(body);
        bytes.extend_from_slice(&[0u8; 3]);
        let sum = fnv1a64(&bytes);
        bytes.extend_from_slice(&sum.to_le_bytes());
        let err = Checkpoint::from_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("trailing"), "{err}");
    }

    #[test]
    fn scenario_digest_gates_verify_and_scan() {
        let dir = std::env::temp_dir().join(format!("ppkm_ckpt_scan_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let ckpt = sample(0, 4, train_payload());
        ckpt.save(&dir).unwrap();
        assert!(ckpt.verify_scenario(&[7u8; 32]).is_ok());
        let err = ckpt.verify_scenario(&[8u8; 32]).unwrap_err();
        assert!(err.to_string().contains("scenario digest mismatch"), "{err}");
        // The scan honors the digest filter, skips foreign parties, and
        // shrugs off a torn file.
        assert_eq!(scan_max_ordinal(&dir, 0, &[7u8; 32]), 4);
        assert_eq!(scan_max_ordinal(&dir, 0, &[8u8; 32]), 0);
        assert_eq!(scan_max_ordinal(&dir, 1, &[7u8; 32]), 0);
        std::fs::write(dir.join(Checkpoint::file_name(0, 9)), b"PPKMCKP1 torn").unwrap();
        assert_eq!(scan_max_ordinal(&dir, 0, &[7u8; 32]), 4);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn confirm_digest_binds_ordinal_and_label() {
        let a = confirm_digest(&[1u8; 32], 3, "train.iter.2");
        assert_ne!(a, confirm_digest(&[1u8; 32], 4, "train.iter.2"));
        assert_ne!(a, confirm_digest(&[1u8; 32], 3, "train.iter.1"));
        assert_ne!(a, confirm_digest(&[2u8; 32], 3, "train.iter.2"));
        let ckpt = sample(0, 3, train_payload());
        assert_eq!(
            ckpt.confirm_digest(),
            confirm_digest(&ckpt.scenario, 3, "train.iter.1")
        );
    }
}
