//! Crash-resumable parties: barrier checkpoints and deterministic
//! replay.
//!
//! A party process can be killed at any point — a crashed host, an OOM
//! kill, an injected fault ([`crate::net::fault`]) — and restarted
//! against the same scenario with the same `--ckpt-dir`. The restarted
//! pair negotiates the highest checkpoint **both** parties hold (the
//! resume leg of the `PPKMWRE1` v2 handshake,
//! [`crate::coordinator::remote`]), restores that snapshot, and replays
//! the rest of the pipeline deterministically. The acceptance bar is
//! **bit-identical transcripts**: a killed-and-resumed run must produce
//! the same reveal hashes *and* the same per-phase meter counts as an
//! uninterrupted run (regression-tested in `tests/resume.rs`).
//!
//! ## Checkpoint sites
//!
//! Checkpoints piggyback on existing pipeline boundaries — they add
//! **no flights** of their own:
//!
//! | site label        | payload                  | pipeline(s)           |
//! |-------------------|--------------------------|-----------------------|
//! | `train.iter.{i}`  | [`artifact::TrainState`] | train, fraud, serve, score-via-serve, gateway |
//! | `train.done`      | [`artifact::TrainDoneState`] | serve, gateway    |
//! | `serve.batch.{i}` | [`artifact::ServeState`] | serve, score          |
//!
//! Ordinals are assigned sequentially from 1 in pipeline order; every
//! checkpoint file is kept (`party{p}.{ordinal:05}.ppkmckp`), so the
//! negotiation can settle on *any* common prefix — including after the
//! peers crashed at different points. A resumed run re-writes the
//! ordinals past the common point; determinism makes those re-writes
//! byte-identical, which is what lets a run survive **multiple** kills.
//!
//! ## What restores, what replays
//!
//! Cheap deterministic setup (handshake, backend selection, the
//! `online.init` exchange) is *replayed* — both parties re-execute it
//! symmetrically, so the wire stays in lockstep. Everything expensive
//! or stateful is *restored* from the snapshot: centroid and assignment
//! shares, the dealer PRG stream position ([`crate::util::prng::Prg::skip_to`]),
//! the consumed-material ledger, the bank's fabrication counters, the
//! scorer's warmup cache and already-revealed batch results. The
//! channel [`crate::net::Meter`] is then overwritten with the
//! checkpointed snapshot, which makes the final per-phase counts equal
//! an uninterrupted run's. Wall-clock telemetry (never part of a
//! transcript) restarts from zero on resume.

// The resume path parses untrusted checkpoint files and runs inside
// wire-facing drivers: typed errors only (ppkm-lint
// no-panic-in-wire-paths covers this subtree).
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod artifact;

pub use artifact::{
    BankCounters, Checkpoint, MeterSnapshot, Payload, ServeState, TrainDoneState, TrainState,
    CKPT_MAGIC, CKPT_VERSION,
};

use crate::net::Meter;
use crate::util::error::{Error, Result};
use std::path::PathBuf;

/// One party's checkpoint context, threaded through the pipeline
/// drivers. Disabled (the default) it is inert: every `save` is a
/// no-op and `max_ordinal` is 0, so pipelines that never asked for
/// resumability pay nothing.
///
/// Checkpoint **writes are infallible at the call site**: the drivers
/// they ride in ([`crate::kmeans::secure`]'s party main loop, the serve
/// loop) either cannot fail or must not fail because a telemetry disk
/// filled up. A failed write is stashed, further writes stop, and the
/// scenario runner surfaces the stashed error after the pipeline
/// completes ([`ResumeCtx::take_error`]).
#[derive(Debug)]
pub struct ResumeCtx {
    dir: Option<PathBuf>,
    party: usize,
    scenario: [u8; 32],
    next_ordinal: u32,
    reveals: Vec<(String, String)>,
    resume: Option<Checkpoint>,
    error: Option<Error>,
}

impl ResumeCtx {
    /// An inert context: no directory, every operation a no-op.
    pub fn disabled() -> ResumeCtx {
        ResumeCtx {
            dir: None,
            party: 0,
            scenario: [0u8; 32],
            next_ordinal: 1,
            reveals: Vec::new(),
            resume: None,
            error: None,
        }
    }

    /// A live context writing `party`'s checkpoints for the scenario
    /// with digest `scenario` into `dir`.
    pub fn new(dir: impl Into<PathBuf>, party: usize, scenario: [u8; 32]) -> ResumeCtx {
        ResumeCtx {
            dir: Some(dir.into()),
            party,
            scenario,
            next_ordinal: 1,
            reveals: Vec::new(),
            resume: None,
            error: None,
        }
    }

    /// Whether checkpointing is configured.
    pub fn enabled(&self) -> bool {
        self.dir.is_some()
    }

    /// This party's highest usable on-disk ordinal for the scenario
    /// (0 = none) — the value its handshake hello advertises.
    pub fn max_ordinal(&self) -> u32 {
        match &self.dir {
            Some(dir) => artifact::scan_max_ordinal(dir, self.party, &self.scenario),
            None => 0,
        }
    }

    /// Load the negotiated common checkpoint. A missing or unreadable
    /// file at an ordinal this party *advertised* is a **checkpoint
    /// gap** — a typed [`Error::Protocol`], because the peer has
    /// already committed to resuming from it.
    pub fn load(&mut self, ordinal: u32) -> Result<&Checkpoint> {
        let dir = self.dir.as_ref().ok_or_else(|| {
            Error::Protocol("resume: checkpoint negotiated but checkpointing is disabled".into())
        })?;
        let path = dir.join(Checkpoint::file_name(self.party, ordinal));
        let ckpt = Checkpoint::load(&path).map_err(|e| {
            Error::Protocol(format!(
                "resume: negotiated checkpoint {ordinal} but party{} has no valid copy at {} \
                 ({e}) — checkpoint gap",
                self.party,
                path.display()
            ))
        })?;
        ckpt.verify_scenario(&self.scenario)?;
        if ckpt.ordinal != ordinal || ckpt.party != self.party {
            return Err(Error::Protocol(format!(
                "resume: {} holds ordinal {} for party{}, expected ordinal {ordinal} for party{}",
                path.display(),
                ckpt.ordinal,
                ckpt.party,
                self.party
            )));
        }
        self.next_ordinal = ordinal + 1;
        self.reveals = ckpt.reveals.clone();
        self.resume = Some(ckpt);
        match &self.resume {
            Some(c) => Ok(c),
            // Unreachable (just assigned); typed for the lint contract.
            None => Err(Error::Protocol("resume: checkpoint vanished after load".into())),
        }
    }

    /// Take the loaded checkpoint for the pipeline to restore from
    /// (consumes it; later calls return `None`).
    pub fn take_resume(&mut self) -> Option<Checkpoint> {
        self.resume.take()
    }

    /// Record the transcript reveals accumulated so far; subsequent
    /// [`ResumeCtx::save`] calls embed this prefix so a resumed run can
    /// reconstruct its reveal list exactly.
    pub fn set_reveals(&mut self, reveals: &[(String, String)]) {
        self.reveals = reveals.to_vec();
    }

    /// The reveal prefix restored by [`ResumeCtx::load`] (empty when
    /// starting fresh).
    pub fn reveals(&self) -> &[(String, String)] {
        &self.reveals
    }

    /// Write the next checkpoint in sequence (atomic temp+rename).
    /// No-op when disabled or after a stashed write error.
    pub fn save(&mut self, label: &str, meter: &Meter, payload: Payload) {
        let Some(dir) = self.dir.clone() else { return };
        if self.error.is_some() {
            return;
        }
        let ckpt = Checkpoint {
            party: self.party,
            ordinal: self.next_ordinal,
            label: label.to_string(),
            scenario: self.scenario,
            reveals: self.reveals.clone(),
            meter: meter.snapshot(),
            payload,
        };
        match ckpt.save(&dir) {
            Ok(_) => self.next_ordinal += 1,
            Err(e) => self.error = Some(e),
        }
    }

    /// Surface a checkpoint-write failure stashed by [`ResumeCtx::save`]
    /// (the pipeline output itself is still valid).
    pub fn take_error(&mut self) -> Option<Error> {
        self.error.take()
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::ring::matrix::Mat;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("ppkm_resume_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn train_payload(iter: u32) -> Payload {
        Payload::Train(TrainState {
            iter,
            stop: false,
            mu: Mat::zeros(2, 2),
            c_share: Mat::zeros(4, 2),
            dealer_pos: 7,
            ledger: Default::default(),
            demand: Default::default(),
            step_demands: Default::default(),
        })
    }

    #[test]
    fn save_load_sequence_and_reveal_prefix() {
        let dir = tmpdir("seq");
        let digest = [3u8; 32];
        let mut ctx = ResumeCtx::new(&dir, 1, digest);
        assert_eq!(ctx.max_ordinal(), 0);
        let meter = Meter::new();
        ctx.save("train.iter.0", &meter, train_payload(1));
        ctx.set_reveals(&[("centroids".into(), "beef".into())]);
        ctx.save("train.done", &meter, Payload::TrainDone(TrainDoneState { model: vec![1] }));
        assert!(ctx.take_error().is_none());
        assert_eq!(ctx.max_ordinal(), 2);

        let mut fresh = ResumeCtx::new(&dir, 1, digest);
        let c = fresh.load(2).unwrap();
        assert_eq!(c.label, "train.done");
        assert_eq!(fresh.reveals(), &[("centroids".to_string(), "beef".to_string())]);
        // The next write after resuming from ordinal 2 is ordinal 3.
        fresh.save("serve.batch.0", &meter, train_payload(9));
        assert_eq!(fresh.max_ordinal(), 3);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn a_gap_is_a_typed_protocol_error() {
        let dir = tmpdir("gap");
        std::fs::create_dir_all(&dir).unwrap();
        let mut ctx = ResumeCtx::new(&dir, 0, [0u8; 32]);
        let err = ctx.load(3).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("checkpoint gap"), "{msg}");
        assert!(matches!(err, Error::Protocol(_)));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn disabled_context_is_inert() {
        let mut ctx = ResumeCtx::disabled();
        assert!(!ctx.enabled());
        assert_eq!(ctx.max_ordinal(), 0);
        ctx.save("train.iter.0", &Meter::new(), train_payload(1));
        assert!(ctx.take_error().is_none());
        assert!(ctx.take_resume().is_none());
    }
}
