//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, and positional arguments.

use std::collections::HashMap;

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: HashMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                // `--key=value` or `--key value` or bare flag
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).map(|v| v.parse().expect("integer option")).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).map(|v| v.parse().expect("float option")).unwrap_or(default)
    }

    pub fn get_str<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn mixed_args() {
        let a = parse("train --k 5 --full --iters=10 data.csv");
        assert_eq!(a.positional, vec!["train", "data.csv"]);
        assert_eq!(a.get_usize("k", 0), 5);
        assert_eq!(a.get_usize("iters", 0), 10);
        assert!(a.flag("full"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.get_usize("k", 3), 3);
        assert_eq!(a.get_f64("eps", 0.5), 0.5);
        assert_eq!(a.get_str("mode", "lan"), "lan");
    }
}
