//! HE2SS: convert homomorphic ciphertexts into additive secret shares
//! (paper §3.3).
//!
//! Party A holds `[[X]]_B` (under B's key) where the underlying integer
//! is bounded by `2^value_bits`. A adds a fresh encryption of a
//! statistical mask `r` (`value_bits + κ` bits, κ = 40) — which also
//! rerandomizes the ciphertext — and sends `[[X + r]]` to B. B decrypts
//! and reduces mod 2^64; A keeps `−r mod 2^64`. Shares then satisfy
//! `⟨X⟩_A + ⟨X⟩_B = X mod 2^64` because `X + r` never wraps the
//! plaintext space.

use super::{ct_from_bytes, ct_to_bytes, HeScheme};
use crate::bigint::BigUint;
use crate::net::Chan;
use crate::util::prng::Prg;

/// Statistical security parameter for masking.
pub const KAPPA: usize = 40;

/// Draw a uniform mask of `bits` bits.
pub fn random_mask(bits: usize, prg: &mut Prg) -> BigUint {
    let limbs = (bits + 63) / 64;
    BigUint::from_limbs((0..limbs).map(|_| prg.next_u64()).collect()).mod_pow2(bits)
}

/// A-side: mask ciphertexts and send; returns A's ring shares (−r).
///
/// `cts[i]` encrypts an integer < 2^value_bits under B's key.
/// Single-threaded wrapper over [`he2ss_sender_par`].
pub fn he2ss_sender<S: HeScheme>(
    chan: &mut Chan,
    pk: &S::Pk,
    cts: &[BigUint],
    value_bits: usize,
    prg: &mut Prg,
) -> Vec<u64> {
    he2ss_sender_par::<S>(chan, pk, cts, value_bits, prg, 1)
}

/// [`he2ss_sender`] with the per-ciphertext work (mask sampling, the
/// rerandomizing encryption, the homomorphic add) fanned out across up
/// to `threads` workers. Mask randomness forks one child PRG per
/// ciphertext sequentially, so the masked payload on the wire and the
/// returned shares are bit-identical for any thread count.
pub fn he2ss_sender_par<S: HeScheme>(
    chan: &mut Chan,
    pk: &S::Pk,
    cts: &[BigUint],
    value_bits: usize,
    prg: &mut Prg,
    threads: usize,
) -> Vec<u64> {
    let mask_bits = value_bits + KAPPA;
    assert!(
        BigUint::one().shl(mask_bits + 1).lt(&S::plaintext_space(pk)),
        "mask would overflow plaintext space ({} + {} bits)",
        value_bits,
        KAPPA
    );
    let children: Vec<Prg> = cts.iter().map(|_| prg.fork(0x4D53_4B31)).collect();
    let w = S::ct_bytes(pk);
    let results: Vec<(Vec<u8>, u64)> =
        crate::runtime::pool::parallel_gen(threads, cts.len(), |i| {
            let mut p = children[i].clone();
            let r = random_mask(mask_bits, &mut p);
            let cr = S::encrypt(pk, &r, &mut p);
            let masked = S::add(pk, &cts[i], &cr);
            // A's share: −r mod 2^64.
            let r64 = r.mod_pow2(64).to_u64().unwrap_or(0);
            (ct_to_bytes::<S>(pk, &masked), r64.wrapping_neg())
        });
    let mut payload = Vec::with_capacity(cts.len() * w);
    let mut shares = Vec::with_capacity(cts.len());
    for (bytes, share) in results {
        payload.extend_from_slice(&bytes);
        shares.push(share);
    }
    chan.send_bytes(&payload);
    shares
}

/// B-side: receive masked ciphertexts, decrypt, reduce mod 2^64.
/// Single-threaded wrapper over [`he2ss_receiver_par`].
pub fn he2ss_receiver<S: HeScheme>(
    chan: &mut Chan,
    pk: &S::Pk,
    sk: &S::Sk,
    count: usize,
) -> Vec<u64> {
    he2ss_receiver_par::<S>(chan, pk, sk, count, 1)
}

/// [`he2ss_receiver`] with the decryptions (one modular exponentiation
/// each) fanned out across up to `threads` workers, in frame order.
pub fn he2ss_receiver_par<S: HeScheme>(
    chan: &mut Chan,
    pk: &S::Pk,
    sk: &S::Sk,
    count: usize,
    threads: usize,
) -> Vec<u64> {
    let w = S::ct_bytes(pk);
    let payload = chan.recv_bytes();
    assert_eq!(payload.len(), count * w, "he2ss frame size");
    let chunks: Vec<&[u8]> = payload.chunks_exact(w).collect();
    crate::runtime::pool::parallel_map(threads, &chunks, |_, chunk| {
        let m = S::decrypt(pk, sk, &ct_from_bytes(chunk));
        m.mod_pow2(64).to_u64().unwrap_or(0)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::he::ou::Ou;
    use crate::net::run_two_party;

    #[test]
    fn he2ss_shares_reconstruct_mod_2_64() {
        // B owns the key; A holds encryptions of known values.
        let mut kprg = Prg::new(11);
        let (pk, sk) = Ou::keygen(512, &mut kprg);
        let pk_a = pk.clone();
        let values = vec![0u64, 1, u64::MAX, 0xDEAD_BEEF, 1 << 50];
        let vals_c = values.clone();
        let ((sa, _), (sb, _)) = run_two_party(
            move |c| {
                let mut prg = Prg::new(21);
                let cts: Vec<BigUint> = vals_c
                    .iter()
                    .map(|&v| Ou::encrypt(&pk_a, &BigUint::from_u64(v), &mut prg))
                    .collect();
                he2ss_sender::<Ou>(c, &pk_a, &cts, 64, &mut prg)
            },
            move |c| he2ss_receiver::<Ou>(c, &pk, &sk, 5),
        );
        for i in 0..values.len() {
            assert_eq!(sa[i].wrapping_add(sb[i]), values[i], "lane {i}");
        }
    }

    #[test]
    fn mask_widths() {
        let mut prg = Prg::new(3);
        let m = random_mask(70, &mut prg);
        assert!(m.bits() <= 70);
    }
}
