//! Okamoto-Uchiyama (1998) additively homomorphic encryption.
//!
//! Modulus `n = p²·q`; plaintext space Z_p; `Enc(m; r) = g^m · h^r mod n`
//! with `h = g^n mod n`. Decryption uses the logarithm
//! `L(x) = (x−1)/p` on the subgroup of order p in Z*_{p²}:
//! `m = L(c^{p−1} mod p²) · L(g^{p−1} mod p²)^{−1} mod p`.
//!
//! The paper picks OU over Paillier because every operation is cheaper:
//! exponents in encryption are short (|m| + |r|), and decryption is one
//! (p−1)-exponentiation mod p² instead of a λ-exponentiation mod n².

use super::HeScheme;
use crate::bigint::modular::{mod_inv, Montgomery};
use crate::bigint::prime::gen_prime;
use crate::bigint::BigUint;
use crate::util::prng::Prg;

/// Bits of randomness in `h^r` (statistical hiding of the message in the
/// order-q^... subgroup; 2κ with κ=128, as in production deployments).
const RAND_BITS: usize = 256;

/// Public key: (n, g, h) with Montgomery context for n.
#[derive(Clone)]
pub struct OuPk {
    pub n: BigUint,
    pub g: BigUint,
    pub h: BigUint,
    pub n_bits: usize,
}

/// Secret key: (p, q) with cached decryption constants.
pub struct OuSk {
    pub p: BigUint,
    /// p² (decryption modulus).
    pub p2: BigUint,
    /// L(g^{p−1} mod p²)^{−1} mod p.
    pub gp_inv: BigUint,
}

/// The Okamoto-Uchiyama scheme.
pub struct Ou;

fn l_func(x: &BigUint, p: &BigUint) -> BigUint {
    // L(x) = (x − 1) / p  (exact division on the order-p subgroup)
    x.sub(&BigUint::one()).div(p)
}

impl HeScheme for Ou {
    type Pk = OuPk;
    type Sk = OuSk;

    fn keygen(bits: usize, prg: &mut Prg) -> (OuPk, OuSk) {
        assert!(bits >= 192, "OU modulus must be at least 192 bits (3 primes)");
        let pb = bits / 3;
        loop {
            let p = gen_prime(pb, prg);
            let q = gen_prime(bits - 2 * pb, prg);
            if p == q {
                continue;
            }
            let p2 = p.mul(&p);
            let n = p2.mul(&q);
            let mont_n = Montgomery::new(&n);
            let mont_p2 = Montgomery::new(&p2);
            let pm1 = p.sub(&BigUint::one());
            // Find g with g^{p−1} mod p² of order p (L(·) invertible mod p).
            let mut tries = 0;
            let g = loop {
                tries += 1;
                if tries > 64 {
                    break None; // re-draw primes (astronomically unlikely)
                }
                let cand = BigUint::from_limbs(
                    (0..n.limbs.len()).map(|_| prg.next_u64()).collect(),
                )
                .rem(&n);
                if cand.is_zero() || cand.is_one() {
                    continue;
                }
                let gp = mont_p2.pow(&cand, &pm1);
                if gp.is_one() {
                    continue;
                }
                let l = l_func(&gp, &p);
                if mod_inv(&l, &p).is_some() {
                    break Some((cand, l));
                }
            };
            let Some((g, gl)) = g else { continue };
            let h = mont_n.pow(&g, &n);
            let gp_inv = mod_inv(&gl, &p).unwrap();
            return (
                OuPk { n_bits: n.bits(), n, g, h },
                OuSk { p, p2, gp_inv },
            );
        }
    }

    fn encrypt(pk: &OuPk, m: &BigUint, prg: &mut Prg) -> BigUint {
        let mont = Montgomery::new(&pk.n);
        let r = BigUint::from_limbs((0..RAND_BITS / 64).map(|_| prg.next_u64()).collect());
        let gm = mont.pow(&pk.g, m);
        let hr = mont.pow(&pk.h, &r);
        mont.mul(&gm, &hr)
    }

    fn decrypt(_pk: &OuPk, sk: &OuSk, c: &BigUint) -> BigUint {
        let mont = Montgomery::new(&sk.p2);
        let pm1 = sk.p.sub(&BigUint::one());
        let cp = mont.pow(&c.rem(&sk.p2), &pm1);
        let l = l_func(&cp, &sk.p);
        l.mul(&sk.gp_inv).rem(&sk.p)
    }

    fn add(pk: &OuPk, c1: &BigUint, c2: &BigUint) -> BigUint {
        c1.mul(c2).rem(&pk.n)
    }

    fn smul(pk: &OuPk, c: &BigUint, x: &BigUint) -> BigUint {
        if x.is_zero() {
            // E(0·u) needs a valid encryption of zero: c^0 = 1 is a
            // trivial (but valid) ciphertext.
            return BigUint::one();
        }
        Montgomery::new(&pk.n).pow(c, x)
    }

    fn plaintext_space(pk: &OuPk) -> BigUint {
        // p is secret; expose a safe public lower bound: 2^(n_bits/3 − 1).
        BigUint::one().shl(pk.n_bits / 3 - 1)
    }

    fn ct_bytes(pk: &OuPk) -> usize {
        (pk.n_bits + 7) / 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn keypair() -> (OuPk, OuSk, Prg) {
        let mut prg = Prg::new(42);
        let (pk, sk) = Ou::keygen(384, &mut prg);
        (pk, sk, prg)
    }

    #[test]
    fn encrypt_decrypt_roundtrip() {
        let (pk, sk, mut prg) = keypair();
        for m in [0u64, 1, 42, u64::MAX, 1 << 63] {
            let c = Ou::encrypt(&pk, &BigUint::from_u64(m), &mut prg);
            assert_eq!(Ou::decrypt(&pk, &sk, &c), BigUint::from_u64(m), "m={m}");
        }
    }

    #[test]
    fn ciphertexts_are_randomized() {
        let (pk, _sk, mut prg) = keypair();
        let c1 = Ou::encrypt(&pk, &BigUint::from_u64(5), &mut prg);
        let c2 = Ou::encrypt(&pk, &BigUint::from_u64(5), &mut prg);
        assert_ne!(c1, c2);
    }

    #[test]
    fn additive_homomorphism() {
        let (pk, sk, mut prg) = keypair();
        let c1 = Ou::encrypt(&pk, &BigUint::from_u64(100), &mut prg);
        let c2 = Ou::encrypt(&pk, &BigUint::from_u64(23), &mut prg);
        let sum = Ou::add(&pk, &c1, &c2);
        assert_eq!(Ou::decrypt(&pk, &sk, &sum), BigUint::from_u64(123));
    }

    #[test]
    fn scalar_homomorphism() {
        let (pk, sk, mut prg) = keypair();
        let c = Ou::encrypt(&pk, &BigUint::from_u64(7), &mut prg);
        let c3 = Ou::smul(&pk, &c, &BigUint::from_u64(13));
        assert_eq!(Ou::decrypt(&pk, &sk, &c3), BigUint::from_u64(91));
    }

    #[test]
    fn big_accumulation_stays_exact() {
        // Σ x_i·y_i with 64-bit values: the use pattern of Protocol 2.
        let (pk, sk, mut prg) = keypair();
        let ys = [u64::MAX, 12345, 1 << 40];
        let xs = [3u64, u64::MAX, 7];
        let mut acc = Ou::encrypt(&pk, &BigUint::zero(), &mut prg);
        let mut want = BigUint::zero();
        for (x, y) in xs.iter().zip(&ys) {
            let cy = Ou::encrypt(&pk, &BigUint::from_u64(*y), &mut prg);
            acc = Ou::add(&pk, &acc, &Ou::smul(&pk, &cy, &BigUint::from_u64(*x)));
            want = want.add(&BigUint::from_u64(*x).mul(&BigUint::from_u64(*y)));
        }
        assert_eq!(Ou::decrypt(&pk, &sk, &acc), want);
    }
}
