//! Paillier (1999) additively homomorphic encryption.
//!
//! `n = p·q`, ciphertexts mod n²; `Enc(m; r) = (1+n)^m · r^n mod n² =
//! (1 + m·n) · r^n mod n²`. Decryption with λ = lcm(p−1, q−1):
//! `m = L(c^λ mod n²) · μ mod n`, `μ = L((1+n)^λ mod n²)^{−1} mod n`.
//!
//! Kept alongside OU for the paper's "OU outperforms Paillier over all
//! operations" claim (reproduced in the `ablations` bench).

use super::HeScheme;
use crate::bigint::modular::{lcm, mod_inv, Montgomery};
use crate::bigint::prime::gen_distinct_primes;
use crate::bigint::BigUint;
use crate::util::prng::Prg;

/// Public key (n, n²).
#[derive(Clone)]
pub struct PaillierPk {
    pub n: BigUint,
    pub n2: BigUint,
    pub n_bits: usize,
}

/// Secret key (λ, μ).
pub struct PaillierSk {
    pub lambda: BigUint,
    pub mu: BigUint,
}

/// The Paillier scheme.
pub struct Paillier;

fn l_func(x: &BigUint, n: &BigUint) -> BigUint {
    x.sub(&BigUint::one()).div(n)
}

impl HeScheme for Paillier {
    type Pk = PaillierPk;
    type Sk = PaillierSk;

    fn keygen(bits: usize, prg: &mut Prg) -> (PaillierPk, PaillierSk) {
        assert!(bits >= 128, "Paillier modulus at least 128 bits");
        let (p, q) = gen_distinct_primes(bits / 2, prg);
        let n = p.mul(&q);
        let n2 = n.mul(&n);
        let lambda = lcm(&p.sub(&BigUint::one()), &q.sub(&BigUint::one()));
        // μ = L((1+n)^λ mod n²)^{-1} mod n ; (1+n)^λ mod n² = 1 + λn.
        let gl = BigUint::one().add(&lambda.mul(&n)).rem(&n2);
        let mu = mod_inv(&l_func(&gl, &n), &n).expect("gcd(λn?, n)=1 by construction");
        (PaillierPk { n_bits: n.bits(), n, n2 }, PaillierSk { lambda, mu })
    }

    fn encrypt(pk: &PaillierPk, m: &BigUint, prg: &mut Prg) -> BigUint {
        assert!(m.lt(&pk.n), "plaintext must be < n");
        let mont = Montgomery::new(&pk.n2);
        // r coprime to n (overwhelmingly true for random r < n).
        let r = BigUint::from_limbs((0..pk.n.limbs.len()).map(|_| prg.next_u64()).collect())
            .rem(&pk.n);
        let r = if r.is_zero() { BigUint::one() } else { r };
        // (1+n)^m = 1 + m·n (mod n²)
        let gm = BigUint::one().add(&m.mul(&pk.n)).rem(&pk.n2);
        let rn = mont.pow(&r, &pk.n);
        gm.mul(&rn).rem(&pk.n2)
    }

    fn decrypt(pk: &PaillierPk, sk: &PaillierSk, c: &BigUint) -> BigUint {
        let mont = Montgomery::new(&pk.n2);
        let cl = mont.pow(c, &sk.lambda);
        l_func(&cl, &pk.n).mul(&sk.mu).rem(&pk.n)
    }

    fn add(pk: &PaillierPk, c1: &BigUint, c2: &BigUint) -> BigUint {
        c1.mul(c2).rem(&pk.n2)
    }

    fn smul(pk: &PaillierPk, c: &BigUint, x: &BigUint) -> BigUint {
        if x.is_zero() {
            return BigUint::one();
        }
        Montgomery::new(&pk.n2).pow(c, x)
    }

    fn plaintext_space(pk: &PaillierPk) -> BigUint {
        pk.n.clone()
    }

    fn ct_bytes(pk: &PaillierPk) -> usize {
        (pk.n2.bits() + 7) / 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn keypair() -> (PaillierPk, PaillierSk, Prg) {
        let mut prg = Prg::new(7);
        let (pk, sk) = Paillier::keygen(256, &mut prg);
        (pk, sk, prg)
    }

    #[test]
    fn roundtrip() {
        let (pk, sk, mut prg) = keypair();
        for m in [0u64, 1, 255, u64::MAX] {
            let c = Paillier::encrypt(&pk, &BigUint::from_u64(m), &mut prg);
            assert_eq!(Paillier::decrypt(&pk, &sk, &c), BigUint::from_u64(m));
        }
    }

    #[test]
    fn homomorphisms() {
        let (pk, sk, mut prg) = keypair();
        let c1 = Paillier::encrypt(&pk, &BigUint::from_u64(11), &mut prg);
        let c2 = Paillier::encrypt(&pk, &BigUint::from_u64(31), &mut prg);
        assert_eq!(
            Paillier::decrypt(&pk, &sk, &Paillier::add(&pk, &c1, &c2)),
            BigUint::from_u64(42)
        );
        assert_eq!(
            Paillier::decrypt(&pk, &sk, &Paillier::smul(&pk, &c1, &BigUint::from_u64(5))),
            BigUint::from_u64(55)
        );
    }

    #[test]
    fn randomized_ciphertexts() {
        let (pk, _sk, mut prg) = keypair();
        let a = Paillier::encrypt(&pk, &BigUint::from_u64(9), &mut prg);
        let b = Paillier::encrypt(&pk, &BigUint::from_u64(9), &mut prg);
        assert_ne!(a, b);
    }
}
