//! Additively homomorphic encryption (paper §3.2).
//!
//! Two schemes behind one trait: [`paillier`] and [`ou`]
//! (Okamoto-Uchiyama — the paper's choice, §5.1, since OU outperforms
//! Paillier on all operations; our `ablations` bench reproduces that
//! claim). Ring elements are embedded as non-negative integers; sums of
//! ≤ 2^14 products of two 64-bit values stay below 2^142, far inside the
//! ≥ 600-bit plaintext spaces, so homomorphic sums never wrap before the
//! final reduction mod 2^64 (see [`he2ss`]).

pub mod he2ss;
pub mod ou;
pub mod paillier;

use crate::bigint::BigUint;
use crate::util::prng::Prg;

/// An additively homomorphic public-key scheme.
///
/// Required homomorphisms (paper §3.2): `add(E(u), E(v)) = E(u+v)` and
/// `smul(E(u), x) = E(x·u)` over the scheme's plaintext space.
pub trait HeScheme {
    /// Public key.
    type Pk: Clone + Send + Sync;
    /// Secret key (`Sync` so batch decryption can fan out across
    /// workers — see [`he2ss::he2ss_receiver_par`]).
    type Sk: Send + Sync;

    /// Generate a key pair with modulus of `bits` bits.
    fn keygen(bits: usize, prg: &mut Prg) -> (Self::Pk, Self::Sk);

    /// Encrypt a plaintext (must be < plaintext space).
    fn encrypt(pk: &Self::Pk, m: &BigUint, prg: &mut Prg) -> BigUint;

    /// Decrypt a ciphertext.
    fn decrypt(pk: &Self::Pk, sk: &Self::Sk, c: &BigUint) -> BigUint;

    /// Homomorphic addition of ciphertexts.
    fn add(pk: &Self::Pk, c1: &BigUint, c2: &BigUint) -> BigUint;

    /// Homomorphic scalar multiplication by a plaintext scalar.
    fn smul(pk: &Self::Pk, c: &BigUint, x: &BigUint) -> BigUint;

    /// Size of the plaintext space (messages must be smaller).
    fn plaintext_space(pk: &Self::Pk) -> BigUint;

    /// Serialized ciphertext width in bytes (fixed per key).
    fn ct_bytes(pk: &Self::Pk) -> usize;
}

/// Serialize a ciphertext to the fixed width for `pk`.
pub fn ct_to_bytes<S: HeScheme>(pk: &S::Pk, c: &BigUint) -> Vec<u8> {
    let w = S::ct_bytes(pk);
    let raw = c.to_bytes_be();
    assert!(raw.len() <= w, "ciphertext wider than modulus");
    let mut out = vec![0u8; w - raw.len()];
    out.extend_from_slice(&raw);
    out
}

/// Deserialize a fixed-width ciphertext.
pub fn ct_from_bytes(bytes: &[u8]) -> BigUint {
    BigUint::from_bytes_be(bytes)
}

/// Encrypt a u64 ring element (as a non-negative integer).
pub fn encrypt_u64<S: HeScheme>(pk: &S::Pk, x: u64, prg: &mut Prg) -> BigUint {
    S::encrypt(pk, &BigUint::from_u64(x), prg)
}

/// Encrypt a vector of ring elements on up to `threads` workers.
///
/// Each element's encryption randomness comes from a child PRG forked
/// off `prg` **sequentially** (thread-count independent), then the
/// modular exponentiations — the dominant cost of the HE sparse path —
/// fan out via [`crate::runtime::pool`]. The ciphertext vector is
/// bit-identical for any `threads` value.
pub fn encrypt_u64s_many<S: HeScheme>(
    pk: &S::Pk,
    values: &[u64],
    prg: &mut Prg,
    threads: usize,
) -> Vec<BigUint> {
    let children: Vec<Prg> = values.iter().map(|_| prg.fork(0x454E_4331)).collect();
    crate::runtime::pool::parallel_gen(threads, values.len(), |i| {
        let mut p = children[i].clone();
        S::encrypt(pk, &BigUint::from_u64(values[i]), &mut p)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::he::ou::Ou;

    #[test]
    fn ct_serialization_roundtrip() {
        let mut prg = Prg::new(1);
        let (pk, _sk) = Ou::keygen(384, &mut prg);
        let c = Ou::encrypt(&pk, &BigUint::from_u64(12345), &mut prg);
        let bytes = ct_to_bytes::<Ou>(&pk, &c);
        assert_eq!(bytes.len(), Ou::ct_bytes(&pk));
        assert_eq!(ct_from_bytes(&bytes), c);
    }
}
