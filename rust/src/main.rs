//! ppkmeans launcher.
//!
//! ```text
//! ppkmeans train  [--n 1000] [--d 4] [--k 3] [--iters 10] [--sparse]
//!                 [--partition vertical|horizontal] [--link lan|wan]
//!                 [--tile-rows B] [--tile-flights lockstep|streamed]
//! ppkmeans fraud  [--n 2000] [--k 4] [--iters 8] [--runs 3]
//! ppkmeans bench                      # list bench targets
//! ppkmeans help                       # full option reference
//! ppkmeans version
//! ```

use ppkmeans::cli::Args;
use ppkmeans::coordinator::Session;
use ppkmeans::data::blobs::BlobSpec;
use ppkmeans::data::sparse_gen;
use ppkmeans::kmeans::config::{Partition, SecureKmeansConfig, TileFlights};
use ppkmeans::net::cost::CostModel;

fn print_help() {
    println!("ppkmeans — scalable sparsity-aware privacy-preserving K-means");
    println!();
    println!("USAGE: ppkmeans <train|fraud|bench|help|version> [options]");
    println!();
    println!("train options:");
    println!("  --n N                   samples to generate (default 1000)");
    println!("  --d D                   features (default 4)");
    println!("  --k K                   clusters (default 3)");
    println!("  --iters T               Lloyd iterations (default 10)");
    println!("  --partition P           vertical | horizontal (default vertical)");
    println!("  --sparse                sparse workload through HE Protocol 2");
    println!("  --sparsity F            zero fraction for --sparse data (default 0.5)");
    println!("  --link L                lan | wan cost model (default lan)");
    println!("  --tile-rows B           row-tile the online phase: every matrix");
    println!("                          triple and S1/S3 intermediate is bounded");
    println!("                          by B rows instead of n, so the offline");
    println!("                          demand is uniform per tile and reusable");
    println!("                          across dataset sizes (default: off)");
    println!("  --tile-flights M        lockstep (tiles share flights — zero extra");
    println!("                          rounds) | streamed (one tile per flight");
    println!("                          group — O(B·d) memory, rounds × tiles)");
    println!("                          (default lockstep)");
    println!();
    println!("fraud: runs as a cargo example —");
    println!("  cargo run --release --example fraud_detection -- [--n N --runs R]");
    println!();
    println!("bench: lists the cargo bench targets (tables/figures + tiling)");
}

fn cmd_train(args: &Args) {
    let n = args.get_usize("n", 1000);
    let d = args.get_usize("d", 4);
    let k = args.get_usize("k", 3);
    let iters = args.get_usize("iters", 10);
    let sparse = args.flag("sparse");
    let sparsity = args.get_f64("sparsity", 0.5);
    let partition = match args.get_str("partition", "vertical") {
        "horizontal" => Partition::Horizontal { n_a: n / 2 },
        _ => Partition::Vertical { d_a: (d / 2).max(1) },
    };
    let link = match args.get_str("link", "lan") {
        "wan" => CostModel::wan(),
        _ => CostModel::lan(),
    };
    let tile_rows = args.get("tile-rows").map(|v| match v.parse::<usize>() {
        Ok(b) if b >= 1 => b,
        _ => {
            eprintln!("--tile-rows takes an integer ≥ 1 (got {v})");
            std::process::exit(2);
        }
    });
    let tile_flights = match args.get_str("tile-flights", "lockstep") {
        "streamed" => TileFlights::Streamed,
        "lockstep" => TileFlights::Lockstep,
        other => {
            eprintln!("unknown --tile-flights {other} (use lockstep|streamed)");
            std::process::exit(2);
        }
    };
    let data = if sparse {
        sparse_gen::generate(n, d, k, sparsity, 42)
    } else {
        BlobSpec::new(n, d, k).generate(42)
    };
    let cfg = SecureKmeansConfig {
        k,
        iters,
        partition,
        sparse,
        tile_rows,
        tile_flights,
        ..Default::default()
    };
    let session = Session::new(cfg).with_link(link);
    match session.run(&data) {
        Ok(out) => {
            println!(
                "trained secure K-means: n={n} d={d} k={k} iters={} backend={} tiles={}",
                out.iters_run, out.backend_name, out.tiles_run
            );
            for j in 0..k {
                let c: Vec<String> = out.centroids[j * d..(j + 1) * d]
                    .iter()
                    .map(|v| format!("{v:.4}"))
                    .collect();
                println!("  centroid {j}: [{}]", c.join(", "));
            }
            let on = out.meter_a.total_prefix("online.");
            println!(
                "  online: {} B, {} rounds; offline demand: {} mat triples, {} bit lanes",
                on.bytes_sent,
                on.rounds,
                out.ledger.mat_triples,
                out.ledger.bit_triple_lanes
            );
        }
        Err(e) => {
            eprintln!("train failed: {e}");
            std::process::exit(1);
        }
    }
}

fn main() {
    let args = Args::from_env();
    if args.flag("help") {
        print_help();
        return;
    }
    match args.positional.first().map(|s| s.as_str()) {
        Some("train") => cmd_train(&args),
        Some("fraud") => {
            println!("run: cargo run --release --example fraud_detection -- [--n N --runs R]");
        }
        Some("bench") => {
            println!("bench targets (cargo bench --bench <name>):");
            for (b, what) in [
                ("table1_runtime", "Table 1 — runtime vs M-Kmeans (LAN)"),
                ("table2_comm", "Table 2 — communication vs M-Kmeans"),
                ("fig2_online_offline", "Fig 2 — online/offline per step (WAN)"),
                ("fig3_vectorization", "Fig 3 — vectorization ablation (WAN)"),
                ("fig4_sparse", "Fig 4 — sparse optimization scaling (WAN)"),
                ("tiling", "row tiling — wall/rounds/triple bytes, BENCH_tiling.json"),
                ("ablations", "extras — OU vs Paillier, PJRT vs native"),
            ] {
                println!("  {b:<20} {what}");
            }
        }
        Some("help") => print_help(),
        Some("version") | None => {
            println!("ppkmeans 0.1.0 — scalable sparsity-aware privacy-preserving K-means");
            println!("subcommands: train | fraud | bench | help | version");
        }
        Some(cmd) => {
            eprintln!("unknown subcommand: {cmd}");
            std::process::exit(2);
        }
    }
}
