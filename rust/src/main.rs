//! ppkmeans launcher.
//!
//! ```text
//! ppkmeans train  [--n 1000] [--d 4] [--k 3] [--iters 10] [--sparse]
//!                 [--partition vertical|horizontal] [--link lan|wan]
//! ppkmeans fraud  [--n 2000] [--k 4] [--iters 8] [--runs 3]
//! ppkmeans bench                      # list bench targets
//! ppkmeans version
//! ```

use ppkmeans::cli::Args;
use ppkmeans::coordinator::Session;
use ppkmeans::data::blobs::BlobSpec;
use ppkmeans::data::sparse_gen;
use ppkmeans::kmeans::config::{Partition, SecureKmeansConfig};
use ppkmeans::net::cost::CostModel;

fn cmd_train(args: &Args) {
    let n = args.get_usize("n", 1000);
    let d = args.get_usize("d", 4);
    let k = args.get_usize("k", 3);
    let iters = args.get_usize("iters", 10);
    let sparse = args.flag("sparse");
    let sparsity = args.get_f64("sparsity", 0.5);
    let partition = match args.get_str("partition", "vertical") {
        "horizontal" => Partition::Horizontal { n_a: n / 2 },
        _ => Partition::Vertical { d_a: (d / 2).max(1) },
    };
    let link = match args.get_str("link", "lan") {
        "wan" => CostModel::wan(),
        _ => CostModel::lan(),
    };
    let data = if sparse {
        sparse_gen::generate(n, d, k, sparsity, 42)
    } else {
        BlobSpec::new(n, d, k).generate(42)
    };
    let cfg = SecureKmeansConfig { k, iters, partition, sparse, ..Default::default() };
    let session = Session::new(cfg).with_link(link);
    match session.run(&data) {
        Ok(out) => {
            println!(
                "trained secure K-means: n={n} d={d} k={k} iters={} backend={}",
                out.iters_run, out.backend_name
            );
            for j in 0..k {
                let c: Vec<String> = out.centroids[j * d..(j + 1) * d]
                    .iter()
                    .map(|v| format!("{v:.4}"))
                    .collect();
                println!("  centroid {j}: [{}]", c.join(", "));
            }
            let on = out.meter_a.total_prefix("online.");
            println!(
                "  online: {} B, {} rounds; offline demand: {} mat triples, {} bit lanes",
                on.bytes_sent,
                on.rounds,
                out.ledger.mat_triples,
                out.ledger.bit_triple_lanes
            );
        }
        Err(e) => {
            eprintln!("train failed: {e}");
            std::process::exit(1);
        }
    }
}

fn main() {
    let args = Args::from_env();
    match args.positional.first().map(|s| s.as_str()) {
        Some("train") => cmd_train(&args),
        Some("fraud") => {
            println!("run: cargo run --release --example fraud_detection -- [--n N --runs R]");
        }
        Some("bench") => {
            println!("bench targets (cargo bench --bench <name>):");
            for (b, what) in [
                ("table1_runtime", "Table 1 — runtime vs M-Kmeans (LAN)"),
                ("table2_comm", "Table 2 — communication vs M-Kmeans"),
                ("fig2_online_offline", "Fig 2 — online/offline per step (WAN)"),
                ("fig3_vectorization", "Fig 3 — vectorization ablation (WAN)"),
                ("fig4_sparse", "Fig 4 — sparse optimization scaling (WAN)"),
                ("ablations", "extras — OU vs Paillier, PJRT vs native"),
            ] {
                println!("  {b:<20} {what}");
            }
        }
        Some("version") | None => {
            println!("ppkmeans 0.1.0 — scalable sparsity-aware privacy-preserving K-means");
            println!("subcommands: train | fraud | bench | version");
        }
        Some(cmd) => {
            eprintln!("unknown subcommand: {cmd}");
            std::process::exit(2);
        }
    }
}
