//! ppkmeans launcher.
//!
//! ```text
//! ppkmeans train  [--n 1000] [--d 4] [--k 3] [--iters 10] [--sparse]
//!                 [--partition vertical|horizontal] [--link lan|wan]
//!                 [--tile-rows B] [--tile-flights lockstep|streamed]
//!                 [--threads N] [--lanes auto|1|4|8]
//!                 [--security semi_honest|malicious]
//! ppkmeans fraud  [--n 2000] [--k 4] [--iters 8] [--runs 2] [--rate 0.05]
//! ppkmeans serve  [--n 1000] [--k 4] [--iters 6] [--batch 64]
//!                 [--batches 12] [--prefab 8] [--low-water 2]
//!                 [--refill 4] [--model-dir model] [--link lan|wan]
//! ppkmeans score  [--model-dir model] [--batch 64] [--batches 8]
//!                 [--link lan|wan]
//! ppkmeans gateway [--sessions 8] [--queue 0] [--workers 4] [--batch 32]
//!                 [--batches 8] [--prefab 2] [--low-water 2] [--refill 2]
//!                 [--link lan|wan] [--shape none|lan|wan]
//! ppkmeans party  --role p0|p1|local --scenario file
//!                 [--listen 127.0.0.1:9041 | --connect HOST:PORT]
//!                 [--out transcript.json]
//! ppkmeans bench                      # list bench targets
//! ppkmeans help                       # full option reference
//! ppkmeans version
//! ```

use ppkmeans::cli::Args;
use ppkmeans::coordinator::remote::{self, PartyTranscript, Scenario};
use ppkmeans::coordinator::serve::{gateway_bench_json, serving_bench_json, GatewayReport, ServeReport};
use ppkmeans::coordinator::Session;
use ppkmeans::data::blobs::BlobSpec;
use ppkmeans::data::{fraud_gen, sparse_gen};
use ppkmeans::fraud::{detect_outliers, jaccard, OutlierConfig};
use ppkmeans::kmeans::config::{EsdMode, Partition, SecureKmeansConfig, TileFlights};
use ppkmeans::kmeans::plaintext;
use ppkmeans::net::cost::CostModel;
use ppkmeans::net::fault::FaultMode;
use ppkmeans::net::{Chan, Security, TcpTransport};
use ppkmeans::offline::bank::BankConfig;
use ppkmeans::runtime::pool::Parallelism;
use ppkmeans::runtime::simd::Lanes;
use ppkmeans::serve::driver::{serve_stream, train_model, ServeConfig};
use ppkmeans::serve::gateway::{gateway_stream, GatewayConfig};
use ppkmeans::serve::model::TrainedModel;
use ppkmeans::serve::scorer::score_rounds;
use ppkmeans::util::stats::mean;
use std::path::{Path, PathBuf};

fn print_help() {
    println!("ppkmeans — scalable sparsity-aware privacy-preserving K-means");
    println!();
    println!("USAGE: ppkmeans <train|fraud|serve|score|gateway|party|bench|help|version> [options]");
    println!();
    println!("train options:");
    println!("  --n N                   samples to generate (default 1000)");
    println!("  --d D                   features (default 4)");
    println!("  --k K                   clusters (default 3)");
    println!("  --iters T               Lloyd iterations (default 10)");
    println!("  --partition P           vertical | horizontal (default vertical)");
    println!("  --sparse                sparse workload through HE Protocol 2");
    println!("  --sparsity F            zero fraction for --sparse data (default 0.5)");
    println!("  --link L                lan | wan cost model (default lan)");
    println!("  --tile-rows B           row-tile the online phase: every matrix");
    println!("                          triple and S1/S3 intermediate is bounded");
    println!("                          by B rows instead of n, so the offline");
    println!("                          demand is uniform per tile and reusable");
    println!("                          across dataset sizes (default: off)");
    println!("  --tile-flights M        lockstep (tiles share flights — zero extra");
    println!("                          rounds) | streamed (one tile per flight");
    println!("                          group — O(B·d) memory, rounds × tiles)");
    println!("                          (default lockstep)");
    println!("  --threads N             worker threads per party for local compute");
    println!("                          (offline triple fabrication, HE encryption");
    println!("                          vectors, plaintext-side matmuls). 0 = one");
    println!("                          per core. Deterministic: outputs, reveals");
    println!("                          and flight/byte meters are bit-identical");
    println!("                          for any N (default 1)");
    println!("  --lanes W               packed-lane width for the crypto kernels");
    println!("                          (Speck CTR batches, lockstep hashing, axpy");
    println!("                          sweeps): auto | 1 | 4 | 8. Deterministic");
    println!("                          like --threads: outputs, reveals and meters");
    println!("                          are bit-identical for any W (default 1)");
    println!();
    println!("fraud options (train → outlier detection → Jaccard report):");
    println!("  --n N                   transactions (default 2000)");
    println!("  --k K                   clusters (default 4)");
    println!("  --iters T               Lloyd iterations (default 8)");
    println!("  --runs R                repetitions (default 2)");
    println!("  --rate F                fraud rate / flag rate (default 0.05)");
    println!();
    println!("serve options (train once, save model shares, score a stream):");
    println!("  --n N                   training transactions (default 1000)");
    println!("  --k K / --iters T       clustering geometry (defaults 4 / 6)");
    println!("  --batch B               transactions per micro-batch (default 64)");
    println!("  --batches M             micro-batches to score (default 12;");
    println!("                          the first is the demand probe)");
    println!("  --prefab P              bank batches fabricated up front (default 8)");
    println!("  --low-water W           replenish below W batches (default 2)");
    println!("  --refill R              batches per replenishment (default 4)");
    println!("  --rate F                fraud flag rate → threshold τ (default 0.05)");
    println!("  --model-dir DIR         where party{{0,1}}.ppkmodel go (default model)");
    println!("  --refresh-every M       refresh centroids from the last M scored batches");
    println!("                          every M batches (default 0 = off)");
    println!("  --refresh-alpha A       refresh blend weight μ←μ+α(recent−μ) (default 0.25)");
    println!("  --link L                lan | wan (default lan)");
    println!();
    println!("  --threads N             worker threads per party (0 = one per core;");
    println!("                          bank prefab/refill and batch compute fan out)");
    println!("  --lanes W               packed-lane width (auto|1|4|8, default 1)");
    println!();
    println!("score options (load saved model shares, score a fresh stream):");
    println!("  --model-dir DIR / --batch B / --batches M / --link L / --threads N");
    println!("  --lanes W");
    println!();
    println!("gateway options (train once, score concurrent sessions over one link):");
    println!("  --sessions S            concurrent client sessions offered (default 8)");
    println!("  --queue Q               admission bound: sessions beyond Q are refused");
    println!("                          with a typed overload, 0 = unbounded (default 0)");
    println!("  --workers W             concurrent scoring workers per party (default 4;");
    println!("                          per-session transcripts are identical for any W)");
    println!("  --replenishers R        background bank replenisher threads (default 1;");
    println!("                          0 = fabricate inline on the scoring path)");
    println!("  --shards S              bank shards (default: one per worker)");
    println!("  --batch B / --batches M per-session stream shape (defaults 32 / 8)");
    println!("  --prefab / --low-water / --refill    per-session kit stocking policy");
    println!("                          (defaults 2 / 2 / 2; refill 0 = a dry session");
    println!("                          fails over to a typed overload)");
    println!("  --n / --k / --iters / --rate         training knobs, as for serve");
    println!("  --link L                lan | wan latency model for the report");
    println!();
    println!("train/serve/score/gateway also accept:");
    println!("  --shape S               none | lan | wan — deterministically shape the");
    println!("                          transport to the link (RTT per flight, bandwidth");
    println!("                          pacing per byte) so wall-clock MEASURES the link");
    println!("                          instead of modeling it (--link picks the model");
    println!("                          used for reporting; --shape changes the run)");
    println!();
    println!("train/fraud/serve/score/gateway also accept:");
    println!("  --security S            semi_honest (default — the paper's model, byte-");
    println!("                          identical transcripts to prior releases) |");
    println!("                          malicious — SPDZ-style MAC ledger over every");
    println!("                          flight, settled in one batched 3-flight check per");
    println!("                          phase barrier; tampering aborts both parties with");
    println!("                          a typed MAC-check error naming the phase");
    println!();
    println!("party options (one endpoint of a two-process TCP deployment):");
    println!("  --role R                p0 (listens) | p1 (connects) | local (both");
    println!("                          parties in-process — the reference transcript");
    println!("                          CI diffs the TCP processes against)");
    println!("  --scenario FILE         key = value scenario both processes must share;");
    println!("                          the handshake verifies a digest of it before");
    println!("                          any protocol byte flows (see scenarios/)");
    println!("  --listen ADDR           p0 bind address (default 127.0.0.1:9041)");
    println!("  --connect ADDR          p1 peer address (default 127.0.0.1:9041)");
    println!("  --out FILE              write the deterministic transcript JSON here");
    println!("                          (local mode also writes FILE.p1)");
    println!("  --ckpt-dir DIR          write/resume barrier checkpoints here (party-local;");
    println!("                          overrides the scenario's ckpt_dir). Restarting with");
    println!("                          the same DIR resumes from the highest checkpoint");
    println!("                          both parties hold — transcripts stay bit-identical");
    println!("  --fault-flight N        inject a fault at this party's Nth flight (0 = off;");
    println!("                          party-local, for the kill-and-resume test matrix)");
    println!("  --fault-mode M          kill | drop | trunc | abort (default kill)");
    println!("  --fault-party P         0 | 1 — which party the armed fault applies to");
    println!();
    println!("bench: lists the cargo bench targets (tables/figures + tiling + serving)");
}

fn link_from(args: &Args) -> CostModel {
    match args.get_str("link", "lan") {
        "wan" => CostModel::wan(),
        _ => CostModel::lan(),
    }
}

/// `--shape lan|wan|none`: deterministic link shaping for the run's
/// transport (measured link time), as opposed to `--link` which only
/// selects the *modeled* report.
fn shape_from(args: &Args) -> Option<CostModel> {
    match args.get_str("shape", "none") {
        "none" => None,
        "lan" => Some(CostModel::lan()),
        "wan" => Some(CostModel::wan()),
        other => {
            eprintln!("unknown --shape {other} (use none|lan|wan)");
            std::process::exit(2);
        }
    }
}

/// `--security semi_honest|malicious` (default semi_honest — the
/// paper's model, transcript-identical to before the tier existed).
fn security_from(args: &Args) -> Security {
    match Security::parse(args.get_str("security", "semi_honest")) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    }
}

/// `--threads N` (0 = one worker per core, default 1). Purely a
/// throughput knob: protocol outputs are bit-identical for any value.
fn parallelism_from(args: &Args) -> Parallelism {
    match args.get_usize("threads", 1) {
        0 => Parallelism::auto(),
        n => Parallelism::new(n),
    }
}

/// `--lanes {auto,1,4,8}` (default 1 = scalar reference path). The
/// packed-lane sibling of `--threads`: purely a throughput knob.
fn lanes_from(args: &Args) -> Lanes {
    match args.get_str("lanes", "1") {
        "auto" => Lanes::auto(),
        s => match s.parse::<usize>() {
            Ok(n) if n >= 1 => Lanes::new(n),
            _ => {
                eprintln!("--lanes takes auto or an integer ≥ 1 (got {s})");
                std::process::exit(2);
            }
        },
    }
}

fn cmd_train(args: &Args) {
    let n = args.get_usize("n", 1000);
    let d = args.get_usize("d", 4);
    let k = args.get_usize("k", 3);
    let iters = args.get_usize("iters", 10);
    let sparse = args.flag("sparse");
    let sparsity = args.get_f64("sparsity", 0.5);
    let partition = match args.get_str("partition", "vertical") {
        "horizontal" => Partition::Horizontal { n_a: n / 2 },
        _ => Partition::Vertical { d_a: (d / 2).max(1) },
    };
    let link = link_from(args);
    let tile_rows = args.get("tile-rows").map(|v| match v.parse::<usize>() {
        Ok(b) if b >= 1 => b,
        _ => {
            eprintln!("--tile-rows takes an integer ≥ 1 (got {v})");
            std::process::exit(2);
        }
    });
    let tile_flights = match args.get_str("tile-flights", "lockstep") {
        "streamed" => TileFlights::Streamed,
        "lockstep" => TileFlights::Lockstep,
        other => {
            eprintln!("unknown --tile-flights {other} (use lockstep|streamed)");
            std::process::exit(2);
        }
    };
    let data = if sparse {
        sparse_gen::generate(n, d, k, sparsity, 42)
    } else {
        BlobSpec::new(n, d, k).generate(42)
    };
    let cfg = SecureKmeansConfig {
        k,
        iters,
        partition,
        esd: if sparse { EsdMode::he() } else { EsdMode::Vectorized },
        security: security_from(args),
        tile_rows,
        tile_flights,
        parallelism: parallelism_from(args),
        lanes: lanes_from(args),
        shape: shape_from(args),
        ..Default::default()
    };
    let session = Session::new(cfg).with_link(link);
    match session.run(&data) {
        Ok(out) => {
            println!(
                "trained secure K-means: n={n} d={d} k={k} iters={} backend={} tiles={}",
                out.iters_run, out.backend_name, out.tiles_run
            );
            for j in 0..k {
                let c: Vec<String> = out.centroids[j * d..(j + 1) * d]
                    .iter()
                    .map(|v| format!("{v:.4}"))
                    .collect();
                println!("  centroid {j}: [{}]", c.join(", "));
            }
            let on = out.meter_a.total_prefix("online.");
            println!(
                "  online: {} B, {} rounds; offline demand: {} mat triples, {} bit lanes",
                on.bytes_sent,
                on.rounds,
                out.ledger.mat_triples,
                out.ledger.bit_triple_lanes
            );
        }
        Err(e) => {
            eprintln!("train failed: {e}");
            std::process::exit(1);
        }
    }
}

/// The fraud pipeline: secure joint training → outlier detection →
/// Jaccard against ground truth, with the single-party plaintext
/// baseline for the joint-vs-single gap (paper §5.6).
fn cmd_fraud(args: &Args) {
    let n = args.get_usize("n", 2000);
    let k = args.get_usize("k", 4);
    let iters = args.get_usize("iters", 8);
    let runs = args.get_usize("runs", 2);
    let rate = args.get_f64("rate", 0.05);
    println!("fraud pipeline: n={n} k={k} t={iters}, {runs} run(s), rate={rate}");
    let ocfg = OutlierConfig { rate, min_cluster_frac: 0.02 };
    let mut j_joint = vec![];
    let mut j_single = vec![];
    for run in 0..runs {
        let f = fraud_gen::generate(n, rate, 1000 + run as u128);
        let cfg = SecureKmeansConfig {
            k,
            iters,
            seed: 7 + run as u128,
            partition: Partition::Vertical { d_a: f.d_payment },
            security: security_from(args),
            ..Default::default()
        };
        let out = match ppkmeans::kmeans::secure::run(&f.data, &cfg) {
            Ok(o) => o,
            Err(e) => {
                eprintln!("fraud failed: {e}");
                std::process::exit(1);
            }
        };
        let flagged = detect_outliers(&f.data, &out.centroids, &out.assignments, k, &ocfg);
        j_joint.push(jaccard(&flagged, &f.outliers));

        let pay = f.payment_only();
        let plain = plaintext::kmeans(&pay, k, iters, 7 + run as u128);
        let flagged = detect_outliers(&pay, &plain.centroids, &plain.assignments, k, &ocfg);
        j_single.push(jaccard(&flagged, &f.outliers));
        println!(
            "  run {run}: secure joint J={:.3}   payment-only J={:.3}",
            j_joint[run], j_single[run]
        );
    }
    println!("average Jaccard: joint {:.3}  single-party {:.3}", mean(&j_joint), mean(&j_single));
    println!("(paper shape: joint ≈ 0.86 ≫ single-party ≈ 0.62)");
}

/// Shared tail of `serve` and `score`: pump a stream, report, emit JSON.
fn serve_and_report(
    models: [TrainedModel; 2],
    scfg: &ServeConfig,
    link: &CostModel,
    train_secs: f64,
    stream_seed: u128,
) {
    let k = models[0].k;
    let rows = scfg.batches * scfg.batch_rows;
    let stream = fraud_gen::generate(rows, 0.05, stream_seed);
    if stream.data.d != models[0].d {
        eprintln!(
            "model expects d={} but the generated stream has d={} — \
             score currently serves fraud-shaped (42-feature) models",
            models[0].d,
            stream.data.d
        );
        std::process::exit(2);
    }
    let out = match serve_stream(models, &stream.data, scfg) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("serve failed: {e}");
            std::process::exit(1);
        }
    };
    // One report per link model; the console view is whichever of the
    // pair --link selected, so it can never drift from the JSON's.
    let lan = ServeReport::from_serve(&out, &CostModel::lan());
    let wan = ServeReport::from_serve(&out, &CostModel::wan());
    let report = if *link == CostModel::wan() { &wan } else { &lan };
    println!(
        "scored {} batches × {} rows (budget {} flights/batch = assignment-only, no S3)",
        scfg.batches,
        scfg.batch_rows,
        score_rounds(k)
    );
    for (i, (s, lat)) in
        out.batch_stats.iter().zip(&report.batch_latency_secs).enumerate()
    {
        let tag = if i == 0 { " (probe)" } else { "" };
        println!(
            "  batch {i:>3}: {} rows, {} flagged, {} B, {} rounds, {:.3} ms{tag}",
            s.rows,
            s.flagged,
            s.online.bytes_sent,
            s.online.rounds,
            lat * 1e3
        );
    }
    println!(
        "steady state: mean {:.3} ms/batch, max {:.3} ms, {:.0} tx/s",
        report.mean_latency_secs * 1e3,
        report.max_latency_secs * 1e3,
        report.throughput_rows_per_sec
    );
    println!(
        "bank: prefabricated {} + replenished {} − consumed {} = {} in stock \
         ({} replenishment(s), {} misses, {} B mat triples/batch)",
        out.bank_prefabricated,
        out.bank_replenished,
        out.bank_consumed,
        out.bank_remaining,
        out.bank_replenish_events,
        out.bank_misses,
        out.per_batch_mat_triple_bytes
    );
    let json = serving_bench_json(&out, &lan, &wan, train_secs);
    match std::fs::write("BENCH_serving.json", &json) {
        Ok(()) => println!("wrote BENCH_serving.json"),
        Err(e) => eprintln!("could not write BENCH_serving.json: {e}"),
    }
}

fn serve_cfg_from(args: &Args) -> ServeConfig {
    ServeConfig {
        batch_rows: args.get_usize("batch", 64),
        batches: args.get_usize("batches", 12),
        bank: BankConfig {
            prefab_batches: args.get_usize("prefab", 8),
            low_water: args.get_usize("low-water", 2),
            refill_batches: args.get_usize("refill", 4),
        },
        seed: 0x5E11E,
        parallelism: parallelism_from(args),
        lanes: lanes_from(args),
        shape: shape_from(args),
        refresh_every: args.get_usize("refresh-every", 0),
        refresh_alpha: args.get_f64("refresh-alpha", 0.25),
        security: security_from(args),
    }
}

fn cmd_serve(args: &Args) {
    let n = args.get_usize("n", 1000);
    let k = args.get_usize("k", 4);
    let iters = args.get_usize("iters", 6);
    let rate = args.get_f64("rate", 0.05);
    let dir = PathBuf::from(args.get_str("model-dir", "model"));
    let link = link_from(args);
    let scfg = serve_cfg_from(args);

    println!("training secure K-means for serving: n={n} k={k} t={iters} (vertical 18+24)");
    let f = fraud_gen::generate(n, rate, 77);
    let cfg = SecureKmeansConfig {
        k,
        iters,
        partition: Partition::Vertical { d_a: f.d_payment },
        security: security_from(args),
        parallelism: parallelism_from(args),
        lanes: lanes_from(args),
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let (out, models) = match train_model(&f.data, &cfg, rate) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("train failed: {e}");
            std::process::exit(1);
        }
    };
    let train_secs = t0.elapsed().as_secs_f64();
    println!(
        "  trained in {train_secs:.2}s ({} iters, backend {}); τ = {:.4}",
        out.iters_run, out.backend_name, models[0].tau
    );
    if let Err(e) = std::fs::create_dir_all(&dir) {
        eprintln!("cannot create {}: {e}", dir.display());
        std::process::exit(1);
    }
    for m in &models {
        let path = dir.join(TrainedModel::file_name(m.party));
        if let Err(e) = m.save(&path) {
            eprintln!("cannot save {}: {e}", path.display());
            std::process::exit(1);
        }
        println!("  saved {}", path.display());
    }
    serve_and_report(models, &scfg, &link, train_secs, 4242);
}

fn cmd_score(args: &Args) {
    let dir = PathBuf::from(args.get_str("model-dir", "model"));
    let link = link_from(args);
    let mut scfg = serve_cfg_from(args);
    scfg.batches = args.get_usize("batches", 8);
    let load = |party: usize| -> TrainedModel {
        let path = dir.join(TrainedModel::file_name(party));
        match TrainedModel::load(&path) {
            Ok(m) => m,
            Err(e) => {
                eprintln!(
                    "cannot load {} ({e}) — run `ppkmeans serve` first to train \
                     and persist the model shares",
                    path.display()
                );
                std::process::exit(1);
            }
        }
    };
    let models = [load(0), load(1)];
    println!(
        "loaded model shares from {} (k={}, d={}, τ={:.4})",
        dir.display(),
        models[0].k,
        models[0].d,
        models[0].tau
    );
    serve_and_report(models, &scfg, &link, 0.0, 24_242);
}

/// `ppkmeans gateway`: train once, then score many concurrent sessions
/// over one mux'd party-pair link, backed by the sharded
/// background-replenished material bank. Writes `BENCH_gateway.json`.
fn cmd_gateway(args: &Args) {
    let n = args.get_usize("n", 1000);
    let k = args.get_usize("k", 4);
    let iters = args.get_usize("iters", 6);
    let rate = args.get_f64("rate", 0.05);
    let link = link_from(args);
    let workers = args.get_usize("workers", 4).max(1);
    let gcfg = GatewayConfig {
        sessions: args.get_usize("sessions", 8),
        queue: args.get_usize("queue", 0),
        workers,
        replenishers: args.get_usize("replenishers", 1),
        shards: match args.get_usize("shards", 0) {
            0 => workers,
            s => s,
        },
        batch_rows: args.get_usize("batch", 32),
        batches: args.get_usize("batches", 8),
        bank: BankConfig {
            prefab_batches: args.get_usize("prefab", 2),
            low_water: args.get_usize("low-water", 2),
            refill_batches: args.get_usize("refill", 2),
        },
        seed: 0x6A7E1,
        parallelism: parallelism_from(args),
        lanes: lanes_from(args),
        shape: shape_from(args),
        refresh_every: args.get_usize("refresh-every", 0),
        refresh_alpha: args.get_f64("refresh-alpha", 0.25),
        security: security_from(args),
    };

    println!("training secure K-means for the gateway: n={n} k={k} t={iters} (vertical 18+24)");
    let f = fraud_gen::generate(n, rate, 77);
    let cfg = SecureKmeansConfig {
        k,
        iters,
        partition: Partition::Vertical { d_a: f.d_payment },
        security: security_from(args),
        parallelism: parallelism_from(args),
        lanes: lanes_from(args),
        ..Default::default()
    };
    let (tout, models) = match train_model(&f.data, &cfg, rate) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("train failed: {e}");
            std::process::exit(1);
        }
    };
    println!(
        "  trained ({} iters, backend {}); τ = {:.4}",
        tout.iters_run, tout.backend_name, models[0].tau
    );

    let rows = gcfg.sessions * gcfg.batches * gcfg.batch_rows;
    let stream = fraud_gen::generate(rows, rate, 31_415);
    let queue = if gcfg.queue == 0 { "unbounded".into() } else { gcfg.queue.to_string() };
    println!(
        "gateway: {} session(s) × {} batches × {} rows over one mux'd link \
         ({} worker(s), {} shard(s), queue {queue})",
        gcfg.sessions, gcfg.batches, gcfg.batch_rows, gcfg.workers, gcfg.shards
    );
    let gout = match gateway_stream(models, &stream.data, &gcfg) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("gateway failed: {e}");
            std::process::exit(1);
        }
    };
    for (tag, s) in &gout.a.sessions {
        match s {
            Ok(s) => println!(
                "  session {tag:>3}: {} batches, {} B online, {} flights, {} miss(es)",
                s.results.len(),
                s.online.bytes_sent,
                s.online.rounds,
                s.misses
            ),
            Err(e) => println!("  session {tag:>3}: {e}"),
        }
    }
    let lan = GatewayReport::from_gateway(&gout.a, gcfg.batch_rows, &CostModel::lan());
    let wan = GatewayReport::from_gateway(&gout.a, gcfg.batch_rows, &CostModel::wan());
    let report = if link == CostModel::wan() { &wan } else { &lan };
    println!(
        "admitted {} / rejected {}: p50 {:.3} ms, p99 {:.3} ms, max {:.3} ms, {:.0} tx/s",
        report.admitted,
        report.rejected,
        report.p50_latency_secs * 1e3,
        report.p99_latency_secs * 1e3,
        report.max_latency_secs * 1e3,
        report.throughput_rows_per_sec
    );
    let [pre, rep, con, stock] = report.bank_ledger;
    println!(
        "bank: prefabricated {pre} + replenished {rep} − consumed {con} = {stock} in stock \
         ({} stall(s), {} miss(es))",
        report.bank_stalls, report.bank_misses
    );
    if let Some((_, p)) = gout.meter_a.phases().find(|(ph, _)| *ph == "gateway.mux") {
        println!(
            "link: {} B in {} tagged frames under gateway.mux (per-session meters sum to it)",
            p.bytes_sent, p.msgs_sent
        );
    }
    let sweeps =
        vec![("lan".to_string(), gcfg.sessions, lan), ("wan".to_string(), gcfg.sessions, wan)];
    let json = gateway_bench_json(k, gcfg.batch_rows, gcfg.batches, &sweeps);
    match std::fs::write("BENCH_gateway.json", &json) {
        Ok(()) => println!("wrote BENCH_gateway.json"),
        Err(e) => eprintln!("could not write BENCH_gateway.json: {e}"),
    }
}

/// Print a transcript summary: reveal digests + per-phase wire counts.
fn print_transcript(t: &PartyTranscript) {
    println!(
        "party {} finished pipeline `{}` (scenario {})",
        t.role,
        t.pipeline.as_str(),
        &t.scenario_sha256[..16]
    );
    println!("  reveals:");
    for (k, v) in &t.reveals {
        println!("    {k:<16} {v}");
    }
    println!("  wire (this party):");
    for (phase, p) in &t.phases {
        println!(
            "    {phase:<16} {:>10} B  {:>6} msgs  {:>5} flights",
            p.bytes_sent, p.msgs_sent, p.rounds
        );
    }
}

fn write_transcript(path: &Path, t: &PartyTranscript) {
    match std::fs::write(path, t.to_json()) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => {
            eprintln!("cannot write {}: {e}", path.display());
            std::process::exit(1);
        }
    }
}

/// `ppkmeans party`: one endpoint of a two-process deployment (or the
/// in-process `local` reference that CI diffs the processes against).
fn cmd_party(args: &Args) {
    let scenario_path = match args.get("scenario") {
        Some(p) => PathBuf::from(p),
        None => {
            eprintln!("party requires --scenario <file> (see scenarios/ for examples)");
            std::process::exit(2);
        }
    };
    let mut sc = match Scenario::from_file(&scenario_path) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    // Party-local overrides (none of these enter the scenario digest):
    // checkpointing and fault injection usually differ per process — the
    // killed party and the surviving one share one scenario file.
    if let Some(dir) = args.get("ckpt-dir") {
        sc.ckpt_dir = dir.to_string();
    }
    if let Some(v) = args.get("fault-flight") {
        sc.fault_flight = match v.parse() {
            Ok(n) => n,
            Err(_) => {
                eprintln!("--fault-flight wants an integer (got {v})");
                std::process::exit(2);
            }
        };
    }
    if let Some(v) = args.get("fault-mode") {
        sc.fault_mode = match FaultMode::parse(v) {
            Ok(m) => m,
            Err(e) => {
                eprintln!("{e}");
                std::process::exit(2);
            }
        };
    }
    if let Some(v) = args.get("fault-party") {
        sc.fault_party = match v.parse() {
            Ok(p @ (0 | 1)) => p,
            _ => {
                eprintln!("--fault-party wants 0 or 1 (got {v})");
                std::process::exit(2);
            }
        };
    }
    let out = args.get("out").map(PathBuf::from);
    match args.get_str("role", "") {
        role @ ("p0" | "p1") => {
            let party = if role == "p0" { 0 } else { 1 };
            let transport = if party == 0 {
                let addr = args.get_str("listen", "127.0.0.1:9041");
                println!("[p0] listening on {addr} ...");
                TcpTransport::listen(addr)
            } else {
                let addr = args.get_str("connect", "127.0.0.1:9041");
                println!("[p1] connecting to {addr} ...");
                TcpTransport::connect(addr)
            };
            let transport = match transport {
                Ok(t) => t,
                Err(e) => {
                    eprintln!("transport: {e}");
                    std::process::exit(1);
                }
            };
            let mut chan = Chan::from_tcp(transport, party);
            match remote::run_scenario(&mut chan, &sc) {
                Ok(t) => {
                    print_transcript(&t);
                    if let Some(path) = out {
                        write_transcript(&path, &t);
                    }
                }
                Err(e) => {
                    eprintln!("party run failed: {e}");
                    std::process::exit(1);
                }
            }
        }
        "local" => match remote::run_scenario_local(&sc) {
            Ok((t0, t1)) => {
                print_transcript(&t0);
                if let Some(path) = out {
                    write_transcript(&path, &t0);
                    let mut p1 = path.into_os_string();
                    p1.push(".p1");
                    write_transcript(&PathBuf::from(p1), &t1);
                }
            }
            Err(e) => {
                eprintln!("local run failed: {e}");
                std::process::exit(1);
            }
        },
        "" => {
            eprintln!("party requires --role p0|p1|local");
            std::process::exit(2);
        }
        other => {
            eprintln!("unknown --role {other} (use p0|p1|local)");
            std::process::exit(2);
        }
    }
}

fn main() {
    let args = Args::from_env();
    if args.flag("help") {
        print_help();
        return;
    }
    match args.positional.first().map(|s| s.as_str()) {
        Some("train") => cmd_train(&args),
        Some("fraud") => cmd_fraud(&args),
        Some("serve") => cmd_serve(&args),
        Some("score") => cmd_score(&args),
        Some("gateway") => cmd_gateway(&args),
        Some("party") => cmd_party(&args),
        Some("bench") => {
            println!("bench targets (cargo bench --bench <name>):");
            for (b, what) in [
                ("table1_runtime", "Table 1 — runtime vs M-Kmeans (LAN)"),
                ("table2_comm", "Table 2 — communication vs M-Kmeans"),
                ("fig2_online_offline", "Fig 2 — online/offline per step (WAN)"),
                ("fig3_vectorization", "Fig 3 — vectorization ablation (WAN)"),
                ("fig4_sparse", "Fig 4 — sparse optimization scaling (WAN)"),
                ("tiling", "row tiling — wall/rounds/triple bytes, BENCH_tiling.json"),
                ("serving", "scoring service — latency/throughput, BENCH_serving.json"),
                ("gateway", "mux'd concurrent sessions — BENCH_gateway.json"),
                ("parallel", "multi-core runtime — 1/2/4/8-thread scaling, BENCH_parallel.json"),
                ("ablations", "extras — OU vs Paillier, PJRT vs native"),
            ] {
                println!("  {b:<20} {what}");
            }
        }
        Some("help") => print_help(),
        Some("version") | None => {
            println!("ppkmeans 0.1.0 — scalable sparsity-aware privacy-preserving K-means");
            println!(
                "subcommands: train | fraud | serve | score | gateway | party | bench | help | version"
            );
        }
        Some(cmd) => {
            eprintln!("unknown subcommand: {cmd}");
            std::process::exit(2);
        }
    }
}
