//! BigUint core: representation, comparison, add/sub/mul/shift.

use std::cmp::Ordering;

/// Arbitrary-precision unsigned integer, little-endian u64 limbs,
/// normalized (no trailing zero limbs; zero is the empty limb vector).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct BigUint {
    pub(crate) limbs: Vec<u64>,
}

impl BigUint {
    pub fn zero() -> Self {
        BigUint { limbs: vec![] }
    }

    pub fn one() -> Self {
        BigUint { limbs: vec![1] }
    }

    pub fn from_u64(x: u64) -> Self {
        if x == 0 {
            Self::zero()
        } else {
            BigUint { limbs: vec![x] }
        }
    }

    pub fn from_u128(x: u128) -> Self {
        let lo = x as u64;
        let hi = (x >> 64) as u64;
        let mut b = BigUint { limbs: vec![lo, hi] };
        b.normalize();
        b
    }

    /// From little-endian limbs (normalizes).
    pub fn from_limbs(limbs: Vec<u64>) -> Self {
        let mut b = BigUint { limbs };
        b.normalize();
        b
    }

    /// From big-endian bytes.
    pub fn from_bytes_be(bytes: &[u8]) -> Self {
        let mut limbs = vec![];
        let mut cur: u64 = 0;
        let mut shift = 0;
        for &b in bytes.iter().rev() {
            cur |= (b as u64) << shift;
            shift += 8;
            if shift == 64 {
                limbs.push(cur);
                cur = 0;
                shift = 0;
            }
        }
        if cur != 0 || shift != 0 {
            limbs.push(cur);
        }
        Self::from_limbs(limbs)
    }

    /// To big-endian bytes (minimal length; zero → empty).
    pub fn to_bytes_be(&self) -> Vec<u8> {
        if self.is_zero() {
            return vec![];
        }
        let mut out = Vec::with_capacity(self.limbs.len() * 8);
        for (i, &l) in self.limbs.iter().enumerate().rev() {
            let bytes = l.to_be_bytes();
            if i == self.limbs.len() - 1 {
                // strip leading zeros of the top limb
                let mut started = false;
                for b in bytes {
                    if b != 0 || started {
                        out.push(b);
                        started = true;
                    }
                }
            } else {
                out.extend_from_slice(&bytes);
            }
        }
        out
    }

    pub(crate) fn normalize(&mut self) {
        while self.limbs.last() == Some(&0) {
            self.limbs.pop();
        }
    }

    pub fn is_zero(&self) -> bool {
        self.limbs.is_empty()
    }

    pub fn is_one(&self) -> bool {
        self.limbs.len() == 1 && self.limbs[0] == 1
    }

    pub fn is_even(&self) -> bool {
        self.limbs.first().map(|l| l & 1 == 0).unwrap_or(true)
    }

    /// Bit length (0 for zero).
    pub fn bits(&self) -> usize {
        match self.limbs.last() {
            None => 0,
            Some(&top) => (self.limbs.len() - 1) * 64 + (64 - top.leading_zeros() as usize),
        }
    }

    /// Test bit `i`.
    pub fn bit(&self, i: usize) -> bool {
        let (limb, off) = (i / 64, i % 64);
        self.limbs.get(limb).map(|l| (l >> off) & 1 == 1).unwrap_or(false)
    }

    pub fn to_u64(&self) -> Option<u64> {
        match self.limbs.len() {
            0 => Some(0),
            1 => Some(self.limbs[0]),
            _ => None,
        }
    }

    pub fn cmp_big(&self, other: &BigUint) -> Ordering {
        if self.limbs.len() != other.limbs.len() {
            return self.limbs.len().cmp(&other.limbs.len());
        }
        for i in (0..self.limbs.len()).rev() {
            match self.limbs[i].cmp(&other.limbs[i]) {
                Ordering::Equal => continue,
                o => return o,
            }
        }
        Ordering::Equal
    }

    pub fn lt(&self, other: &BigUint) -> bool {
        self.cmp_big(other) == Ordering::Less
    }

    pub fn add(&self, other: &BigUint) -> BigUint {
        let (a, b) = if self.limbs.len() >= other.limbs.len() {
            (&self.limbs, &other.limbs)
        } else {
            (&other.limbs, &self.limbs)
        };
        let mut out = Vec::with_capacity(a.len() + 1);
        let mut carry = 0u64;
        for i in 0..a.len() {
            let bv = b.get(i).copied().unwrap_or(0);
            let (s1, c1) = a[i].overflowing_add(bv);
            let (s2, c2) = s1.overflowing_add(carry);
            out.push(s2);
            carry = (c1 as u64) + (c2 as u64);
        }
        if carry > 0 {
            out.push(carry);
        }
        BigUint::from_limbs(out)
    }

    /// `self - other`; panics on underflow (caller guarantees order).
    pub fn sub(&self, other: &BigUint) -> BigUint {
        debug_assert!(!self.lt(other), "BigUint::sub underflow");
        let mut out = Vec::with_capacity(self.limbs.len());
        let mut borrow = 0u64;
        for i in 0..self.limbs.len() {
            let bv = other.limbs.get(i).copied().unwrap_or(0);
            let (d1, b1) = self.limbs[i].overflowing_sub(bv);
            let (d2, b2) = d1.overflowing_sub(borrow);
            out.push(d2);
            borrow = (b1 as u64) + (b2 as u64);
        }
        debug_assert_eq!(borrow, 0);
        BigUint::from_limbs(out)
    }

    /// Schoolbook / Karatsuba multiplication (Karatsuba above 32 limbs).
    pub fn mul(&self, other: &BigUint) -> BigUint {
        if self.is_zero() || other.is_zero() {
            return BigUint::zero();
        }
        if self.limbs.len().min(other.limbs.len()) >= 32 {
            return self.karatsuba(other);
        }
        self.mul_school(other)
    }

    fn mul_school(&self, other: &BigUint) -> BigUint {
        let mut out = vec![0u64; self.limbs.len() + other.limbs.len()];
        for (i, &a) in self.limbs.iter().enumerate() {
            if a == 0 {
                continue;
            }
            let mut carry = 0u128;
            for (j, &b) in other.limbs.iter().enumerate() {
                let cur = out[i + j] as u128 + (a as u128) * (b as u128) + carry;
                out[i + j] = cur as u64;
                carry = cur >> 64;
            }
            let mut idx = i + other.limbs.len();
            while carry > 0 {
                let cur = out[idx] as u128 + carry;
                out[idx] = cur as u64;
                carry = cur >> 64;
                idx += 1;
            }
        }
        BigUint::from_limbs(out)
    }

    fn karatsuba(&self, other: &BigUint) -> BigUint {
        let m = self.limbs.len().max(other.limbs.len()) / 2;
        let (a0, a1) = self.split_at_limb(m);
        let (b0, b1) = other.split_at_limb(m);
        let z0 = a0.mul(&b0);
        let z2 = a1.mul(&b1);
        let z1 = a0.add(&a1).mul(&b0.add(&b1)).sub(&z0).sub(&z2);
        z0.add(&z1.shl_limbs(m)).add(&z2.shl_limbs(2 * m))
    }

    fn split_at_limb(&self, m: usize) -> (BigUint, BigUint) {
        if self.limbs.len() <= m {
            (self.clone(), BigUint::zero())
        } else {
            (
                BigUint::from_limbs(self.limbs[..m].to_vec()),
                BigUint::from_limbs(self.limbs[m..].to_vec()),
            )
        }
    }

    pub(crate) fn shl_limbs(&self, m: usize) -> BigUint {
        if self.is_zero() {
            return BigUint::zero();
        }
        let mut limbs = vec![0u64; m];
        limbs.extend_from_slice(&self.limbs);
        BigUint::from_limbs(limbs)
    }

    /// Left shift by `n` bits.
    pub fn shl(&self, n: usize) -> BigUint {
        if self.is_zero() {
            return BigUint::zero();
        }
        let (limb_shift, bit_shift) = (n / 64, n % 64);
        let mut limbs = vec![0u64; limb_shift];
        if bit_shift == 0 {
            limbs.extend_from_slice(&self.limbs);
        } else {
            let mut carry = 0u64;
            for &l in &self.limbs {
                limbs.push((l << bit_shift) | carry);
                carry = l >> (64 - bit_shift);
            }
            if carry != 0 {
                limbs.push(carry);
            }
        }
        BigUint::from_limbs(limbs)
    }

    /// Right shift by `n` bits.
    pub fn shr(&self, n: usize) -> BigUint {
        let (limb_shift, bit_shift) = (n / 64, n % 64);
        if limb_shift >= self.limbs.len() {
            return BigUint::zero();
        }
        let src = &self.limbs[limb_shift..];
        let mut limbs = Vec::with_capacity(src.len());
        if bit_shift == 0 {
            limbs.extend_from_slice(src);
        } else {
            for i in 0..src.len() {
                let hi = src.get(i + 1).copied().unwrap_or(0);
                limbs.push((src[i] >> bit_shift) | (hi << (64 - bit_shift)));
            }
        }
        BigUint::from_limbs(limbs)
    }

    /// `self mod 2^n`.
    pub fn mod_pow2(&self, n: usize) -> BigUint {
        let (limb, bit) = (n / 64, n % 64);
        if limb >= self.limbs.len() {
            return self.clone();
        }
        let mut limbs = self.limbs[..=limb.min(self.limbs.len() - 1)].to_vec();
        if bit == 0 {
            limbs.truncate(limb);
        } else if limb < limbs.len() {
            limbs[limb] &= (1u64 << bit) - 1;
        }
        BigUint::from_limbs(limbs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn big(s: &[u64]) -> BigUint {
        BigUint::from_limbs(s.to_vec())
    }

    #[test]
    fn add_sub_roundtrip_with_carries() {
        let a = big(&[u64::MAX, u64::MAX, 3]);
        let b = big(&[1, 0, 0]);
        let s = a.add(&b);
        assert_eq!(s, big(&[0, 0, 4]));
        assert_eq!(s.sub(&b), a);
    }

    #[test]
    fn mul_matches_u128() {
        let a = BigUint::from_u64(0xFFFF_FFFF_FFFF_FFFF);
        let b = BigUint::from_u64(0xFEDC_BA98_7654_3210);
        let p = a.mul(&b);
        let want = (0xFFFF_FFFF_FFFF_FFFFu128) * 0xFEDC_BA98_7654_3210u128 as u128;
        assert_eq!(p, BigUint::from_u128(want));
    }

    #[test]
    fn karatsuba_matches_schoolbook() {
        // Build two ~40-limb numbers from a simple recurrence.
        let mut al = vec![0u64; 40];
        let mut bl = vec![0u64; 37];
        let mut x = 0x1234_5678_9ABC_DEF0u64;
        for l in al.iter_mut().chain(bl.iter_mut()) {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            *l = x;
        }
        let a = big(&al);
        let b = big(&bl);
        assert_eq!(a.karatsuba(&b), a.mul_school(&b));
    }

    #[test]
    fn shifts() {
        let a = BigUint::from_u64(0b1011);
        assert_eq!(a.shl(65), big(&[0, 0b10110]));
        assert_eq!(a.shl(65).shr(65), a);
        assert_eq!(a.shr(2), BigUint::from_u64(0b10));
        assert_eq!(a.shr(100), BigUint::zero());
    }

    #[test]
    fn bytes_roundtrip() {
        let a = big(&[0xDEAD_BEEF, 0x1234]);
        let bytes = a.to_bytes_be();
        assert_eq!(BigUint::from_bytes_be(&bytes), a);
        assert_eq!(BigUint::from_bytes_be(&[]), BigUint::zero());
        assert_eq!(BigUint::from_u64(256).to_bytes_be(), vec![1, 0]);
    }

    #[test]
    fn bits_and_bit() {
        assert_eq!(BigUint::zero().bits(), 0);
        assert_eq!(BigUint::from_u64(1).bits(), 1);
        assert_eq!(big(&[0, 1]).bits(), 65);
        assert!(big(&[0, 1]).bit(64));
        assert!(!big(&[0, 1]).bit(63));
    }

    #[test]
    fn mod_pow2() {
        let a = big(&[u64::MAX, 0b111]);
        assert_eq!(a.mod_pow2(64), big(&[u64::MAX]));
        assert_eq!(a.mod_pow2(66), big(&[u64::MAX, 0b11]));
        assert_eq!(a.mod_pow2(200), a);
    }

    #[test]
    fn cmp_orders() {
        assert!(BigUint::from_u64(2).lt(&big(&[0, 1])));
        assert!(!big(&[0, 1]).lt(&big(&[0, 1])));
        assert!(big(&[5, 1]).lt(&big(&[4, 2])));
    }
}
