//! Arbitrary-precision unsigned integers (u64 limbs, little-endian).
//!
//! `num-bigint` is not available offline, and the paper's HE layer
//! (Okamoto-Uchiyama / Paillier with 2048-bit keys, §5.1) and the
//! DH-based base OTs need modular arithmetic on multi-thousand-bit
//! numbers — so we build the substrate: schoolbook/Karatsuba
//! multiplication, Knuth Algorithm-D division, Montgomery modular
//! exponentiation, Miller-Rabin primality and prime generation.

pub mod arith;
pub mod div;
pub mod modular;
pub mod prime;

pub use arith::BigUint;
