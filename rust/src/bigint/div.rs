//! Division and remainder: Knuth TAOCP vol. 2 Algorithm D.

use super::arith::BigUint;

impl BigUint {
    /// Quotient and remainder; panics if `divisor` is zero.
    pub fn divmod(&self, divisor: &BigUint) -> (BigUint, BigUint) {
        assert!(!divisor.is_zero(), "division by zero");
        if self.lt(divisor) {
            return (BigUint::zero(), self.clone());
        }
        if divisor.limbs.len() == 1 {
            return self.divmod_u64(divisor.limbs[0]);
        }
        self.divmod_knuth(divisor)
    }

    /// `self mod m`.
    pub fn rem(&self, m: &BigUint) -> BigUint {
        self.divmod(m).1
    }

    /// `self / m`.
    pub fn div(&self, m: &BigUint) -> BigUint {
        self.divmod(m).0
    }

    fn divmod_u64(&self, d: u64) -> (BigUint, BigUint) {
        let mut q = vec![0u64; self.limbs.len()];
        let mut r: u128 = 0;
        for i in (0..self.limbs.len()).rev() {
            let cur = (r << 64) | self.limbs[i] as u128;
            q[i] = (cur / d as u128) as u64;
            r = cur % d as u128;
        }
        (BigUint::from_limbs(q), BigUint::from_u64(r as u64))
    }

    /// Knuth Algorithm D for multi-limb divisors.
    fn divmod_knuth(&self, divisor: &BigUint) -> (BigUint, BigUint) {
        // D1: normalize so the top divisor limb has its high bit set.
        let shift = divisor.limbs.last().unwrap().leading_zeros() as usize;
        let u = self.shl(shift);
        let v = divisor.shl(shift);
        let n = v.limbs.len();
        let m = u.limbs.len() - n;

        // Working copy of the dividend with one extra high limb.
        let mut un = u.limbs.clone();
        un.push(0);
        let vn = &v.limbs;
        let vtop = vn[n - 1];
        let vsecond = vn[n - 2];

        let mut q = vec![0u64; m + 1];
        // D2-D7: main loop over quotient digits, most significant first.
        for j in (0..=m).rev() {
            // D3: estimate q̂ from the top two dividend limbs.
            let numer = ((un[j + n] as u128) << 64) | un[j + n - 1] as u128;
            let mut qhat = numer / vtop as u128;
            let mut rhat = numer % vtop as u128;
            // Correct q̂ down at most twice.
            while qhat >> 64 != 0
                || qhat * vsecond as u128 > ((rhat << 64) | un[j + n - 2] as u128)
            {
                qhat -= 1;
                rhat += vtop as u128;
                if rhat >> 64 != 0 {
                    break;
                }
            }
            // D4: multiply-subtract u[j..j+n] -= q̂ · v.
            let mut borrow: i128 = 0;
            let mut carry: u128 = 0;
            for i in 0..n {
                let p = qhat * vn[i] as u128 + carry;
                carry = p >> 64;
                let sub = un[j + i] as i128 - (p as u64) as i128 + borrow;
                un[j + i] = sub as u64;
                borrow = sub >> 64;
            }
            let sub = un[j + n] as i128 - carry as i128 + borrow;
            un[j + n] = sub as u64;

            // D5/D6: if we went negative, add one divisor back.
            if sub < 0 {
                qhat -= 1;
                let mut carry = 0u128;
                for i in 0..n {
                    let s = un[j + i] as u128 + vn[i] as u128 + carry;
                    un[j + i] = s as u64;
                    carry = s >> 64;
                }
                un[j + n] = un[j + n].wrapping_add(carry as u64);
            }
            q[j] = qhat as u64;
        }

        let quotient = BigUint::from_limbs(q);
        let remainder = BigUint::from_limbs(un[..n].to_vec()).shr(shift);
        (quotient, remainder)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prg;

    fn rand_big(prg: &mut Prg, limbs: usize) -> BigUint {
        BigUint::from_limbs((0..limbs).map(|_| prg.next_u64()).collect())
    }

    #[test]
    fn small_division_matches_u128() {
        let a = BigUint::from_u128(0x1234_5678_9ABC_DEF0_1122_3344_5566_7788);
        let b = BigUint::from_u64(0x9999_8888_7777);
        let (q, r) = a.divmod(&b);
        let aa = 0x1234_5678_9ABC_DEF0_1122_3344_5566_7788u128;
        let bb = 0x9999_8888_7777u128;
        assert_eq!(q, BigUint::from_u128(aa / bb));
        assert_eq!(r, BigUint::from_u128(aa % bb));
    }

    #[test]
    fn knuth_reconstructs_for_random_inputs() {
        let mut prg = Prg::new(1234);
        for trial in 0..60 {
            let an = 2 + (trial % 10);
            let bn = 2 + (trial % 5);
            let a = rand_big(&mut prg, an);
            let b = rand_big(&mut prg, bn);
            if b.is_zero() {
                continue;
            }
            let (q, r) = a.divmod(&b);
            assert!(r.lt(&b), "remainder must be < divisor (trial {trial})");
            assert_eq!(q.mul(&b).add(&r), a, "trial {trial}");
        }
    }

    #[test]
    fn dividend_smaller_than_divisor() {
        let a = BigUint::from_u64(5);
        let b = BigUint::from_u128(u128::MAX);
        let (q, r) = a.divmod(&b);
        assert!(q.is_zero());
        assert_eq!(r, a);
    }

    #[test]
    fn exact_division() {
        let mut prg = Prg::new(9);
        let a = rand_big(&mut prg, 6);
        let b = rand_big(&mut prg, 3);
        let p = a.mul(&b);
        let (q, r) = p.divmod(&b);
        assert!(r.is_zero());
        assert_eq!(q, a);
    }

    #[test]
    #[should_panic(expected = "division by zero")]
    fn div_by_zero_panics() {
        let _ = BigUint::from_u64(1).divmod(&BigUint::zero());
    }

    #[test]
    fn d6_addback_case() {
        // Construct a case that exercises the rare add-back branch:
        // classic trigger uses dividend with pattern forcing qhat
        // overestimate. (2^128 - 1) / (2^64 + 3) style inputs.
        let a = BigUint::from_limbs(vec![u64::MAX, u64::MAX, u64::MAX]);
        let b = BigUint::from_limbs(vec![3, 1]);
        let (q, r) = a.divmod(&b);
        assert_eq!(q.mul(&b).add(&r), a);
        assert!(r.lt(&b));
    }
}
