//! Modular arithmetic: Montgomery multiplication/exponentiation,
//! modular inverse (binary extended GCD).

use super::arith::BigUint;

/// Montgomery context for a fixed odd modulus `n`: precomputes
/// `n' = -n^{-1} mod 2^64` and `R^2 mod n` for CIOS multiplication.
pub struct Montgomery {
    pub n: BigUint,
    n_limbs: Vec<u64>,
    n_prime: u64,
    r2: BigUint,
    k: usize,
}

impl Montgomery {
    pub fn new(n: &BigUint) -> Montgomery {
        assert!(!n.is_even() && !n.is_zero(), "Montgomery needs odd modulus");
        let k = n.limbs.len();
        // n' = -n^{-1} mod 2^64 via Newton's iteration on 64-bit inverse.
        let n0 = n.limbs[0];
        let mut inv = n0; // correct to 3 bits (odd)
        for _ in 0..5 {
            inv = inv.wrapping_mul(2u64.wrapping_sub(n0.wrapping_mul(inv)));
        }
        let n_prime = inv.wrapping_neg();
        // R^2 mod n where R = 2^(64k)
        let r2 = BigUint::one().shl(128 * k).rem(n);
        Montgomery { n: n.clone(), n_limbs: n.limbs.clone(), n_prime, r2, k }
    }

    /// CIOS Montgomery product: returns `a·b·R^{-1} mod n` for inputs in
    /// Montgomery form (little-endian limb vectors of length ≤ k).
    fn mont_mul_limbs(&self, a: &[u64], b: &[u64]) -> Vec<u64> {
        let k = self.k;
        let mut t = vec![0u64; k + 2];
        for i in 0..k {
            let ai = a.get(i).copied().unwrap_or(0);
            // t += ai * b
            let mut carry: u128 = 0;
            for j in 0..k {
                let bj = b.get(j).copied().unwrap_or(0);
                let cur = t[j] as u128 + ai as u128 * bj as u128 + carry;
                t[j] = cur as u64;
                carry = cur >> 64;
            }
            let cur = t[k] as u128 + carry;
            t[k] = cur as u64;
            t[k + 1] = (cur >> 64) as u64;
            // m = t[0] * n' mod 2^64 ; t += m*n ; t >>= 64
            let m = t[0].wrapping_mul(self.n_prime);
            let cur = t[0] as u128 + m as u128 * self.n_limbs[0] as u128;
            let mut carry: u128 = cur >> 64;
            for j in 1..k {
                let cur = t[j] as u128 + m as u128 * self.n_limbs[j] as u128 + carry;
                t[j - 1] = cur as u64;
                carry = cur >> 64;
            }
            let cur = t[k] as u128 + carry;
            t[k - 1] = cur as u64;
            t[k] = t[k + 1].wrapping_add((cur >> 64) as u64);
            t[k + 1] = 0;
        }
        t.truncate(k + 1);
        // Conditional subtraction to land in [0, n).
        let mut res = BigUint::from_limbs(t);
        if !res.lt(&self.n) {
            res = res.sub(&self.n);
        }
        res.limbs.resize(self.k, 0);
        res.limbs.clone()
    }

    fn to_mont(&self, a: &BigUint) -> Vec<u64> {
        let mut al = a.rem(&self.n).limbs;
        al.resize(self.k, 0);
        let mut r2 = self.r2.limbs.clone();
        r2.resize(self.k, 0);
        self.mont_mul_limbs(&al, &r2)
    }

    fn from_mont(&self, a: &[u64]) -> BigUint {
        let one = {
            let mut v = vec![0u64; self.k];
            v[0] = 1;
            v
        };
        BigUint::from_limbs(self.mont_mul_limbs(a, &one))
    }

    /// `base^exp mod n` with left-to-right square-and-multiply in
    /// Montgomery form.
    pub fn pow(&self, base: &BigUint, exp: &BigUint) -> BigUint {
        if exp.is_zero() {
            return BigUint::one().rem(&self.n);
        }
        let bm = self.to_mont(base);
        let mut acc = self.to_mont(&BigUint::one());
        for i in (0..exp.bits()).rev() {
            acc = self.mont_mul_limbs(&acc, &acc);
            if exp.bit(i) {
                acc = self.mont_mul_limbs(&acc, &bm);
            }
        }
        self.from_mont(&acc)
    }

    /// Modular multiplication `a·b mod n` through Montgomery form.
    pub fn mul(&self, a: &BigUint, b: &BigUint) -> BigUint {
        let am = self.to_mont(a);
        let bm = self.to_mont(b);
        self.from_mont(&self.mont_mul_limbs(&am, &bm))
    }
}

/// `a^e mod n` convenience (builds a context per call; hot paths keep a
/// [`Montgomery`] around). Falls back to simple square-and-multiply with
/// division for even moduli.
pub fn mod_pow(base: &BigUint, exp: &BigUint, n: &BigUint) -> BigUint {
    if !n.is_even() {
        return Montgomery::new(n).pow(base, exp);
    }
    // Even modulus (rare; e.g. 2^l): plain square-and-multiply.
    let mut acc = BigUint::one().rem(n);
    let b = base.rem(n);
    for i in (0..exp.bits()).rev() {
        acc = acc.mul(&acc).rem(n);
        if exp.bit(i) {
            acc = acc.mul(&b).rem(n);
        }
    }
    acc
}

/// Modular inverse `a^{-1} mod n` (extended Euclid); `None` if gcd ≠ 1.
pub fn mod_inv(a: &BigUint, n: &BigUint) -> Option<BigUint> {
    // Iterative extended Euclid on signed coefficient tracking.
    let (mut r0, mut r1) = (n.clone(), a.rem(n));
    // Coefficients of a: (s, sign) pairs tracked as BigUint with sign bits.
    let (mut t0, mut t0_neg) = (BigUint::zero(), false);
    let (mut t1, mut t1_neg) = (BigUint::one(), false);
    while !r1.is_zero() {
        let (q, r2) = r0.divmod(&r1);
        // t2 = t0 - q*t1 with sign handling
        let qt1 = q.mul(&t1);
        let (t2, t2_neg) = signed_sub(&t0, t0_neg, &qt1, t1_neg);
        r0 = r1;
        r1 = r2;
        t0 = t1;
        t0_neg = t1_neg;
        t1 = t2;
        t1_neg = t2_neg;
    }
    if !r0.is_one() {
        return None;
    }
    Some(if t0_neg { n.sub(&t0.rem(n)) } else { t0.rem(n) })
}

/// (a, a_neg) - (b, b_neg) in sign-magnitude.
fn signed_sub(a: &BigUint, a_neg: bool, b: &BigUint, b_neg: bool) -> (BigUint, bool) {
    match (a_neg, b_neg) {
        (false, true) => (a.add(b), false),
        (true, false) => (a.add(b), true),
        (an, _) => {
            if b.lt(a) || a == b {
                (a.sub(b), an)
            } else {
                (b.sub(a), !an)
            }
        }
    }
}

/// Greatest common divisor (binary / Euclid hybrid).
pub fn gcd(a: &BigUint, b: &BigUint) -> BigUint {
    let (mut x, mut y) = (a.clone(), b.clone());
    while !y.is_zero() {
        let r = x.rem(&y);
        x = y;
        y = r;
    }
    x
}

/// Least common multiple.
pub fn lcm(a: &BigUint, b: &BigUint) -> BigUint {
    if a.is_zero() || b.is_zero() {
        return BigUint::zero();
    }
    a.div(&gcd(a, b)).mul(b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prg;

    fn rand_big(prg: &mut Prg, limbs: usize) -> BigUint {
        BigUint::from_limbs((0..limbs).map(|_| prg.next_u64()).collect())
    }

    #[test]
    fn mont_mul_matches_naive() {
        let mut prg = Prg::new(55);
        for _ in 0..20 {
            let mut n = rand_big(&mut prg, 4);
            n.limbs[0] |= 1; // odd
            let m = Montgomery::new(&n);
            let a = rand_big(&mut prg, 4).rem(&n);
            let b = rand_big(&mut prg, 4).rem(&n);
            assert_eq!(m.mul(&a, &b), a.mul(&b).rem(&n));
        }
    }

    #[test]
    fn pow_small_cases() {
        let n = BigUint::from_u64(1000000007);
        let m = Montgomery::new(&n);
        assert_eq!(m.pow(&BigUint::from_u64(2), &BigUint::from_u64(10)), BigUint::from_u64(1024));
        // Fermat: a^(p-1) = 1 mod p
        assert_eq!(
            m.pow(&BigUint::from_u64(123456), &BigUint::from_u64(1000000006)),
            BigUint::one()
        );
    }

    #[test]
    fn pow_multi_limb_fermat() {
        // p = 2^89 - 1 is a Mersenne prime.
        let p = BigUint::one().shl(89).sub(&BigUint::one());
        let m = Montgomery::new(&p);
        let a = BigUint::from_u128(0xDEAD_BEEF_1234_5678_9ABC);
        let pm1 = p.sub(&BigUint::one());
        assert_eq!(m.pow(&a, &pm1), BigUint::one());
    }

    #[test]
    fn mod_inv_inverts() {
        let mut prg = Prg::new(66);
        let p = BigUint::one().shl(89).sub(&BigUint::one()); // prime
        for _ in 0..10 {
            let a = rand_big(&mut prg, 2).rem(&p);
            if a.is_zero() {
                continue;
            }
            let inv = mod_inv(&a, &p).expect("inverse exists mod prime");
            assert_eq!(a.mul(&inv).rem(&p), BigUint::one());
        }
    }

    #[test]
    fn mod_inv_none_when_not_coprime() {
        let a = BigUint::from_u64(6);
        let n = BigUint::from_u64(9);
        assert!(mod_inv(&a, &n).is_none());
    }

    #[test]
    fn gcd_lcm() {
        let a = BigUint::from_u64(12);
        let b = BigUint::from_u64(18);
        assert_eq!(gcd(&a, &b), BigUint::from_u64(6));
        assert_eq!(lcm(&a, &b), BigUint::from_u64(36));
    }

    #[test]
    fn mod_pow_even_modulus() {
        let n = BigUint::from_u64(1 << 20);
        let r = mod_pow(&BigUint::from_u64(3), &BigUint::from_u64(100), &n);
        // 3^100 mod 2^20 computed independently
        let mut acc: u64 = 1;
        for _ in 0..100 {
            acc = acc.wrapping_mul(3) % (1 << 20);
        }
        assert_eq!(r, BigUint::from_u64(acc));
    }
}
