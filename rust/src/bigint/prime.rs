//! Primality testing and prime generation (Miller-Rabin).

use super::arith::BigUint;
use super::modular::Montgomery;
use crate::util::prng::Prg;

/// Small primes for trial division before Miller-Rabin.
const SMALL_PRIMES: [u64; 30] = [
    2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41, 43, 47, 53, 59, 61, 67, 71, 73, 79, 83, 89,
    97, 101, 103, 107, 109, 113,
];

/// Miller-Rabin probabilistic primality test with `rounds` random bases
/// (error ≤ 4^-rounds).
pub fn is_prime(n: &BigUint, rounds: usize, prg: &mut Prg) -> bool {
    if n.bits() <= 6 {
        let v = n.to_u64().unwrap();
        return SMALL_PRIMES.contains(&v);
    }
    if n.is_even() {
        return false;
    }
    for &p in &SMALL_PRIMES {
        if n.rem(&BigUint::from_u64(p)).is_zero() {
            return n.to_u64() == Some(p);
        }
    }
    // n − 1 = d · 2^s
    let n1 = n.sub(&BigUint::one());
    let s = {
        let mut s = 0;
        while !n1.bit(s) {
            s += 1;
        }
        s
    };
    let d = n1.shr(s);
    let mont = Montgomery::new(n);
    'witness: for _ in 0..rounds {
        // Random base in [2, n-2].
        let a = loop {
            let bits = n.bits();
            let limbs = (bits + 63) / 64;
            let mut cand = BigUint::from_limbs((0..limbs).map(|_| prg.next_u64()).collect());
            cand = cand.rem(n);
            if !cand.is_zero() && !cand.is_one() && cand.lt(&n1) {
                break cand;
            }
        };
        let mut x = mont.pow(&a, &d);
        if x.is_one() || x == n1 {
            continue 'witness;
        }
        for _ in 0..s - 1 {
            x = mont.mul(&x, &x);
            if x == n1 {
                continue 'witness;
            }
        }
        return false;
    }
    true
}

/// Generate a random prime of exactly `bits` bits.
pub fn gen_prime(bits: usize, prg: &mut Prg) -> BigUint {
    assert!(bits >= 8);
    loop {
        let limbs = (bits + 63) / 64;
        let mut cand = BigUint::from_limbs((0..limbs).map(|_| prg.next_u64()).collect());
        cand = cand.mod_pow2(bits);
        // Force top bit (exact size) and bottom bit (odd).
        cand = {
            let mut l = cand.limbs.clone();
            l.resize(limbs, 0);
            l[(bits - 1) / 64] |= 1u64 << ((bits - 1) % 64);
            l[0] |= 1;
            BigUint::from_limbs(l)
        };
        if is_prime(&cand, 12, prg) {
            return cand;
        }
    }
}

/// Generate a prime `p` of `bits` bits such that `p-1` has a known large
/// prime factor structure is NOT required here; Okamoto-Uchiyama needs
/// plain random primes; Paillier needs two distinct primes.
pub fn gen_distinct_primes(bits: usize, prg: &mut Prg) -> (BigUint, BigUint) {
    let p = gen_prime(bits, prg);
    loop {
        let q = gen_prime(bits, prg);
        if q != p {
            return (p, q);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_primes_and_composites() {
        let mut prg = Prg::new(1);
        for p in [2u64, 3, 5, 97, 1000000007, 4294967291] {
            assert!(is_prime(&BigUint::from_u64(p), 16, &mut prg), "{p} is prime");
        }
        for c in [1u64, 4, 100, 1000000006, 4294967295, 561 /* Carmichael */] {
            assert!(!is_prime(&BigUint::from_u64(c), 16, &mut prg), "{c} is composite");
        }
    }

    #[test]
    fn mersenne_89_is_prime() {
        let mut prg = Prg::new(2);
        let p = BigUint::one().shl(89).sub(&BigUint::one());
        assert!(is_prime(&p, 12, &mut prg));
        let c = BigUint::one().shl(87).sub(&BigUint::one()); // 2^87-1 composite
        assert!(!is_prime(&c, 12, &mut prg));
    }

    #[test]
    fn generated_primes_have_exact_bits() {
        let mut prg = Prg::new(3);
        for bits in [64, 96, 128] {
            let p = gen_prime(bits, &mut prg);
            assert_eq!(p.bits(), bits);
            assert!(!p.is_even());
        }
    }

    #[test]
    fn distinct_primes_differ() {
        let mut prg = Prg::new(4);
        let (p, q) = gen_distinct_primes(64, &mut prg);
        assert_ne!(p, q);
    }
}
