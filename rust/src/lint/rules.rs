//! The protocol-invariant rule catalog and the matching engine.
//!
//! Each rule is a set of forbidden tokens plus a **module-path scope**
//! telling the engine where the tokens are forbidden. Two scope shapes
//! cover every invariant this repo cares about:
//!
//! * [`Scope::BannedIn`] — the tokens are forbidden *inside* the listed
//!   module subtrees (e.g. `HashMap` in protocol-state modules);
//! * [`Scope::ConfinedTo`] — the tokens are forbidden *everywhere
//!   except* the listed subtrees (e.g. `Instant::now` confined to
//!   `util::timer`, `net::shape` and the bench/report layer).
//!
//! Scopes ship with built-in defaults (see [`default_rules`]) and are
//! overridable from the `lint.rules` config file
//! ([`super::config`]); per-site escapes use the
//! `// lint:allow(rule-id): justification` marker parsed by the lexer.
//! An allow **without** a justification does not suppress — it turns
//! into a finding of its own, so every escape hatch is documented at
//! the point of use. The rule rationale lives in
//! `docs/STATIC_ANALYSIS.md`.

use super::lexer::LexedLine;

/// Where a rule's tokens are forbidden, as module-path prefixes
/// (`offline` covers `offline::store`; `serve::driver` covers exactly
/// that subtree).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Scope {
    /// Forbidden inside these subtrees, allowed elsewhere.
    BannedIn(Vec<String>),
    /// Forbidden everywhere *except* these subtrees.
    ConfinedTo(Vec<String>),
}

/// One named protocol invariant.
#[derive(Debug, Clone)]
pub struct Rule {
    /// Stable id, used in findings, `lint:allow(…)` and `lint.rules`.
    pub id: &'static str,
    /// One-line statement of the invariant (shown with every finding).
    pub summary: &'static str,
    /// Forbidden tokens. Tokens that start/end with an identifier
    /// character are matched with word boundaries, so `Instant` never
    /// fires inside `Instantaneous`.
    pub tokens: Vec<&'static str>,
    /// Where the tokens are forbidden.
    pub scope: Scope,
    /// Extra exempted module prefixes (from `lint.rules` `exempt.*`
    /// keys) — subtrees where this rule is silenced even in scope.
    pub exempt: Vec<String>,
}

/// One rule violation (or an unjustified suppression of one).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// The violated rule's id.
    pub rule: &'static str,
    /// Repo-relative path of the offending file.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// The token that matched.
    pub token: String,
    /// Extra context (e.g. a note that a suppression lacked its
    /// justification). Empty for a plain violation.
    pub note: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}:{}: `{}`", self.rule, self.file, self.line, self.token)?;
        if !self.note.is_empty() {
            write!(f, " ({})", self.note)?;
        }
        Ok(())
    }
}

/// The built-in rule catalog with its default scopes. The `lint.rules`
/// config file can re-scope every rule but cannot invent new ones —
/// rules are code, scopes are policy.
pub fn default_rules() -> Vec<Rule> {
    let paths = |v: &[&str]| v.iter().map(|s| s.to_string()).collect::<Vec<_>>();
    vec![
        Rule {
            id: "no-unordered-iteration",
            summary: "HashMap/HashSet iteration order is nondeterministic; protocol \
                      state must use ordered containers (BTreeMap/Vec) so transcripts \
                      and snapshots replay bit-identically",
            tokens: vec!["HashMap", "HashSet"],
            scope: Scope::BannedIn(paths(&[
                "ss", "offline", "kmeans", "mkmeans", "serve", "net", "runtime",
            ])),
            exempt: vec![],
        },
        Rule {
            id: "no-wallclock-in-protocol",
            summary: "wall-clock reads are confined to the timer/shaper/bench layer; \
                      share and reveal computation must never observe time",
            tokens: vec!["Instant", "SystemTime"],
            scope: Scope::ConfinedTo(paths(&[
                "util::timer",
                "net::shape",
                "offline::timed",
                "bench",
                "main",
            ])),
            exempt: vec![],
        },
        Rule {
            id: "no-rogue-threads",
            summary: "threads are created only by runtime::pool, the one fan-out site \
                      whose determinism contract (index-ordered writeback, \
                      thread-count-independent outputs) is regression-tested",
            tokens: vec!["thread::spawn", "thread::Builder", "thread::scope", "spawn_scoped"],
            scope: Scope::ConfinedTo(paths(&["runtime::pool"])),
            exempt: vec![],
        },
        Rule {
            id: "no-unmetered-io",
            summary: "raw sockets live only inside net/, so every wire byte rides the \
                      Meter and flight/byte budgets stay exact",
            tokens: vec!["TcpStream", "TcpListener", "UdpSocket"],
            scope: Scope::ConfinedTo(paths(&["net"])),
            exempt: vec![],
        },
        Rule {
            id: "no-ambient-entropy",
            summary: "all randomness flows from the seeded PRG (util::prng); OS \
                      entropy or hasher randomization would break transcript replay",
            tokens: vec![
                "RandomState",
                "thread_rng",
                "OsRng",
                "getrandom",
                "from_entropy",
                "SystemRandom",
            ],
            scope: Scope::ConfinedTo(vec![]),
            exempt: vec![],
        },
        Rule {
            id: "no-unchecked-open",
            summary: "raw share opens (reconstruct/reconstruct_to) bypass the deferred \
                      MAC ledger's value authentication; outside the sanctioned \
                      semi-honest modules a reveal must go through open_auth or \
                      reconstruct_committed so the malicious tier stays end-to-end \
                      checked",
            tokens: vec!["reconstruct(", "reconstruct_to("],
            scope: Scope::ConfinedTo(paths(&["ss::share", "kmeans::secure", "mkmeans"])),
            exempt: vec![],
        },
        Rule {
            id: "no-panic-in-wire-paths",
            summary: "wire-facing code returns typed Errors (a misbehaving peer must \
                      yield a clean process exit, not a panic); asserts on local \
                      invariants are fine",
            tokens: vec![
                ".unwrap()",
                ".expect(",
                "panic!",
                "unreachable!",
                "todo!",
                "unimplemented!",
            ],
            scope: Scope::BannedIn(paths(&["net", "serve::driver", "serve::gateway"])),
            exempt: vec![],
        },
    ]
}

/// Whether `module` (e.g. `net::tcp`) falls under `prefix` (`net`).
fn under(module: &str, prefix: &str) -> bool {
    module == prefix || module.starts_with(&format!("{prefix}::"))
}

/// Whether a rule applies to a module at all, given its scope and
/// exemptions.
pub fn in_scope(rule: &Rule, module: &str) -> bool {
    if rule.exempt.iter().any(|p| under(module, p)) {
        return false;
    }
    match &rule.scope {
        Scope::BannedIn(mods) => mods.iter().any(|p| under(module, p)),
        Scope::ConfinedTo(mods) => !mods.iter().any(|p| under(module, p)),
    }
}

/// Is `c` part of an identifier (for word-boundary checks)?
fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Find `token` in `code` respecting word boundaries on whichever ends
/// of the token are identifier characters.
fn token_hits(code: &str, token: &str) -> bool {
    let first_ident = token.chars().next().map(is_ident).unwrap_or(false);
    let last_ident = token.chars().last().map(is_ident).unwrap_or(false);
    let mut start = 0;
    while let Some(pos) = code[start..].find(token) {
        let at = start + pos;
        let left_ok = !first_ident
            || at == 0
            || !code[..at].chars().next_back().map(is_ident).unwrap_or(false);
        let right_ok = !last_ident
            || !code[at + token.len()..].chars().next().map(is_ident).unwrap_or(false);
        if left_ok && right_ok {
            return true;
        }
        start = at + token.len().max(1);
    }
    false
}

/// Run every in-scope rule over a lexed file.
///
/// `file` is the repo-relative path used in findings; `module` is the
/// crate module path (`offline::store`). Suppressions apply to the
/// marker's own line and to the line directly below it (so a marker
/// can sit on its own line above the offending statement); a marker
/// with no justification never suppresses and instead surfaces as a
/// finding, keeping "silent" escapes impossible.
pub fn check_lines(
    rules: &[Rule],
    file: &str,
    module: &str,
    lines: &[LexedLine],
) -> Vec<Finding> {
    let mut findings = Vec::new();
    for rule in rules {
        if !in_scope(rule, module) {
            continue;
        }
        for (idx, line) in lines.iter().enumerate() {
            if line.in_test {
                continue;
            }
            let Some(token) = rule.tokens.iter().find(|t| token_hits(&line.code, t)) else {
                continue;
            };
            // An allow on this line or the line above covers the hit.
            let find_allow = |l: &LexedLine| {
                l.allows.iter().find(|a| a.rule == rule.id).cloned()
            };
            let relevant = find_allow(line)
                .or_else(|| idx.checked_sub(1).and_then(|p| find_allow(&lines[p])));
            match relevant {
                Some(a) if a.justified => continue,
                Some(_) => findings.push(Finding {
                    rule: rule.id,
                    file: file.to_string(),
                    line: line.line_no,
                    token: (*token).to_string(),
                    note: "suppressed without a justification — write \
                           `lint:allow(rule): why`"
                        .into(),
                }),
                None => findings.push(Finding {
                    rule: rule.id,
                    file: file.to_string(),
                    line: line.line_no,
                    token: (*token).to_string(),
                    note: String::new(),
                }),
            }
        }
    }
    findings
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::lexer::lex;

    fn rule(id: &str) -> Rule {
        default_rules().into_iter().find(|r| r.id == id).unwrap()
    }

    #[test]
    fn scope_prefix_matching() {
        let r = rule("no-unordered-iteration");
        assert!(in_scope(&r, "offline::store"));
        assert!(in_scope(&r, "net"));
        assert!(!in_scope(&r, "fraud::jaccard"), "fraud is outside the banned set");
        assert!(!in_scope(&r, "cli"));
        let w = rule("no-wallclock-in-protocol");
        assert!(!in_scope(&w, "util::timer"));
        assert!(!in_scope(&w, "net::shape"));
        assert!(in_scope(&w, "net::tcp"), "confinement is per-subtree, not per-layer");
        assert!(in_scope(&w, "kmeans::secure"));
        let o = rule("no-unchecked-open");
        assert!(!in_scope(&o, "ss::share"), "the primitive's home module is sanctioned");
        assert!(!in_scope(&o, "kmeans::secure"));
        assert!(!in_scope(&o, "mkmeans::protocol"));
        assert!(in_scope(&o, "ss::mux"), "the rest of ss must open through the ledger");
        assert!(in_scope(&o, "serve::scorer"));
    }

    #[test]
    fn word_boundaries_protect_longer_identifiers() {
        assert!(token_hits("let t = Instant::now();", "Instant"));
        assert!(!token_hits("let t = Instantaneous::now();", "Instant"));
        assert!(token_hits("x.unwrap()", ".unwrap()"));
        assert!(!token_hits("x.unwrap_or(0)", ".unwrap()"));
        assert!(!token_hits("x.unwrap_or_default()", ".unwrap()"));
        assert!(token_hits("x.expect(\"msg\")", ".expect("));
        assert!(!token_hits("x.expect_err(\"msg\")", ".expect("));
        assert!(token_hits("core::panic!(\"x\")", "panic!"));
        assert!(!token_hits("should_panic", "panic!"));
        assert!(token_hits("let m = reconstruct(chan, &z);", "reconstruct("));
        assert!(token_hits("share::reconstruct_to(chan, &z, 1)", "reconstruct_to("));
        assert!(
            !token_hits("reconstruct_committed(chan, &z, \"p\")", "reconstruct("),
            "the authenticated wrapper is not the raw primitive"
        );
        assert!(!token_hits("mk_reconstruct(chan)", "reconstruct("));
    }

    #[test]
    fn findings_name_rule_file_and_line() {
        let lines = lex("use std::collections::HashMap;\nfn f() {}\n");
        let f = check_lines(&default_rules(), "src/offline/store.rs", "offline::store", &lines);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "no-unordered-iteration");
        assert_eq!(f[0].line, 1);
        assert_eq!(f[0].token, "HashMap");
        let shown = f[0].to_string();
        assert!(shown.contains("src/offline/store.rs:1"), "{shown}");
    }

    #[test]
    fn justified_allow_suppresses_unjustified_does_not() {
        let src = "let x = q.pop(); // lint:allow(no-panic-in-wire-paths): single \
                   sanctioned abort\nlet y = z.unwrap();";
        let lines = lex(&format!("{}{}", "x.unwrap(); ", src));
        let f = check_lines(&default_rules(), "src/net/a.rs", "net::a", &lines);
        // Line 1 has an unsuppressed unwrap AND a justified allow (for
        // pop — rule matches the unwrap token on the same line, so the
        // allow covers it); line 2 is covered by the line-above marker.
        assert!(f.is_empty(), "{f:?}");
        let lines = lex("z.unwrap(); // lint:allow(no-panic-in-wire-paths)");
        let f = check_lines(&default_rules(), "src/net/a.rs", "net::a", &lines);
        assert_eq!(f.len(), 1);
        assert!(f[0].note.contains("without a justification"));
    }

    #[test]
    fn test_regions_are_exempt() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); }\n}";
        let f = check_lines(&default_rules(), "src/net/a.rs", "net::a", &lex(src));
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn asserts_and_sleep_are_not_violations() {
        let src = "assert_eq!(a, b);\nassert!(x > 0, \"msg\");\nstd::thread::sleep(d);";
        let f = check_lines(&default_rules(), "src/net/a.rs", "net::a", &lex(src));
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn exempt_prefix_silences_a_rule() {
        let mut rules = default_rules();
        for r in &mut rules {
            if r.id == "no-wallclock-in-protocol" {
                r.exempt.push("kmeans::legacy".into());
            }
        }
        let lines = lex("use std::time::Instant;");
        assert!(check_lines(&rules, "f", "kmeans::legacy::x", &lines).is_empty());
        assert_eq!(check_lines(&rules, "f", "kmeans::secure", &lines).len(), 1);
    }
}
