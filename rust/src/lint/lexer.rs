//! Comment/string-aware line lexer for the protocol-invariant linter.
//!
//! `ppkm-lint` matches *tokens* against source lines, so the one thing
//! the lexer must get right is never letting a token inside a comment,
//! a string literal, or a char literal produce a finding: a rustdoc
//! example mentioning `HashMap`, an error message containing
//! `".unwrap()"`, or a raw string holding a whole fixture file must all
//! be invisible to the rules. The lexer therefore rewrites each source
//! line into a *code skeleton* — comments stripped, string/char literal
//! **contents** blanked to spaces (the delimiting quotes stay, so
//! columns keep their meaning) — and the rule engine only ever looks at
//! the skeleton.
//!
//! Three pieces of real Rust syntax make this harder than a regex:
//!
//! * **nested block comments** — `/* outer /* inner */ still out */` is
//!   one comment; the lexer tracks the nesting depth;
//! * **raw strings** — `r"…"`, `r#"…"#` (any hash count) and their
//!   byte-string forms do not process escapes, and the body may contain
//!   `"` freely; the closing delimiter is `"` followed by the same hash
//!   count;
//! * **char literals vs lifetimes** — `'a'` is a literal but `'a` in
//!   `&'a str` is a lifetime; the lexer uses the standard two-character
//!   lookahead disambiguation (a `'` starts a literal iff the next char
//!   is a backslash or the char after next is a closing `'`).
//!
//! The lexer also performs the two line-level extractions the rule
//! engine needs: `lint:allow(rule-id)` suppression markers found inside
//! line comments (with their mandatory justification text), and
//! `#[cfg(test)]`-region tracking via brace depth, so test-only code is
//! exempt from the rules without any per-rule special casing.

/// An inline suppression marker parsed from a line comment:
/// `// lint:allow(rule-id): justification`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Allow {
    /// The rule id inside the parentheses.
    pub rule: String,
    /// Whether a non-empty justification follows the marker — a bare
    /// `lint:allow(rule)` with no `: why` text does **not** suppress.
    pub justified: bool,
}

/// One source line after lexing.
#[derive(Debug, Clone)]
pub struct LexedLine {
    /// 1-based line number in the source file.
    pub line_no: usize,
    /// The code skeleton: comments removed, string/char literal
    /// contents blanked to spaces, everything else verbatim.
    pub code: String,
    /// Suppression markers found in this line's comments.
    pub allows: Vec<Allow>,
    /// Whether the line sits inside a `#[cfg(test)]` region (the
    /// attribute line itself and the braced item it gates).
    pub in_test: bool,
}

/// Lexer state carried across lines.
enum State {
    /// Plain code.
    Normal,
    /// Inside a block comment at the given nesting depth.
    Block(usize),
    /// Inside a normal (escaped) string literal.
    Str,
    /// Inside a raw string literal closed by `"` + this many `#`s.
    RawStr(usize),
}

/// Scan a comment's text for `lint:allow(rule-id)` markers and append
/// them to `allows`. A marker is justified when a `:` follows the
/// closing parenthesis with non-whitespace text after it.
fn scan_allows(comment: &str, allows: &mut Vec<Allow>) {
    let mut rest = comment;
    while let Some(pos) = rest.find("lint:allow(") {
        let after = &rest[pos + "lint:allow(".len()..];
        let Some(close) = after.find(')') else { return };
        let rule = after[..close].trim().to_string();
        let tail = &after[close + 1..];
        let justified = tail
            .strip_prefix(':')
            .map(|t| !t.trim().is_empty())
            .unwrap_or(false);
        if !rule.is_empty() {
            allows.push(Allow { rule, justified });
        }
        rest = tail;
    }
}

/// Lex a whole source file into per-line code skeletons.
///
/// The returned lines are in file order and cover every input line
/// (blank and comment-only lines produce empty/whitespace skeletons).
pub fn lex(source: &str) -> Vec<LexedLine> {
    let mut lines = Vec::new();
    let mut state = State::Normal;
    for (idx, raw) in source.lines().enumerate() {
        let chars: Vec<char> = raw.chars().collect();
        let mut code = String::with_capacity(chars.len());
        let mut allows = Vec::new();
        let mut i = 0;
        while i < chars.len() {
            match state {
                State::Block(depth) => {
                    if chars[i] == '*' && i + 1 < chars.len() && chars[i + 1] == '/' {
                        state = if depth == 1 { State::Normal } else { State::Block(depth - 1) };
                        i += 2;
                    } else if chars[i] == '/' && i + 1 < chars.len() && chars[i + 1] == '*' {
                        state = State::Block(depth + 1);
                        i += 2;
                    } else {
                        i += 1;
                    }
                    continue;
                }
                State::Str => {
                    if chars[i] == '\\' {
                        // Escape: blank both chars, never close on \".
                        code.push(' ');
                        code.push(' ');
                        i += 2;
                    } else if chars[i] == '"' {
                        code.push('"');
                        state = State::Normal;
                        i += 1;
                    } else {
                        code.push(' ');
                        i += 1;
                    }
                    continue;
                }
                State::RawStr(hashes) => {
                    if chars[i] == '"' && i + hashes < chars.len() {
                        let closes = (1..=hashes).all(|h| chars[i + h] == '#');
                        if closes {
                            code.push('"');
                            for _ in 0..hashes {
                                code.push('#');
                            }
                            state = State::Normal;
                            i += 1 + hashes;
                            continue;
                        }
                    } else if chars[i] == '"' && hashes == 0 {
                        code.push('"');
                        state = State::Normal;
                        i += 1;
                        continue;
                    }
                    code.push(' ');
                    i += 1;
                    continue;
                }
                State::Normal => {}
            }
            let c = chars[i];
            // Line comment: scan the remainder for allow markers, drop it.
            if c == '/' && i + 1 < chars.len() && chars[i + 1] == '/' {
                let comment: String = chars[i..].iter().collect();
                scan_allows(&comment, &mut allows);
                break;
            }
            // Block comment start.
            if c == '/' && i + 1 < chars.len() && chars[i + 1] == '*' {
                state = State::Block(1);
                i += 2;
                continue;
            }
            // Raw (byte) string start: r"…", r#"…"#, br"…", br#"…"# —
            // only when the `r` does not end an identifier.
            if c == 'r' || (c == 'b' && i + 1 < chars.len() && chars[i + 1] == 'r') {
                let prev_ident = i > 0 && (chars[i - 1].is_alphanumeric() || chars[i - 1] == '_');
                let r_at = if c == 'b' { i + 1 } else { i };
                if !prev_ident && r_at < chars.len() && chars[r_at] == 'r' {
                    let mut j = r_at + 1;
                    let mut hashes = 0;
                    while j < chars.len() && chars[j] == '#' {
                        hashes += 1;
                        j += 1;
                    }
                    if j < chars.len() && chars[j] == '"' {
                        for &p in &chars[i..=j] {
                            code.push(p);
                        }
                        state = State::RawStr(hashes);
                        i = j + 1;
                        continue;
                    }
                }
            }
            // Normal (or byte) string start.
            if c == '"' || (c == 'b' && i + 1 < chars.len() && chars[i + 1] == '"') {
                if c == 'b' {
                    code.push('b');
                    i += 1;
                }
                code.push('"');
                state = State::Str;
                i += 1;
                continue;
            }
            // Char literal vs lifetime: a `'` begins a literal iff the
            // next char is a backslash, or the char after next is the
            // closing `'` (so `'a'` is a literal, `'a` in `&'a T` is a
            // lifetime and passes through untouched).
            if c == '\'' {
                let is_escape = i + 1 < chars.len() && chars[i + 1] == '\\';
                let is_plain = i + 2 < chars.len() && chars[i + 2] == '\'' && chars[i + 1] != '\'';
                if is_escape {
                    code.push('\'');
                    let mut j = i + 1;
                    // Blank to the closing quote (handles \n, \u{…}, \\).
                    while j < chars.len() {
                        if chars[j] == '\\' {
                            code.push(' ');
                            code.push(' ');
                            j += 2;
                            continue;
                        }
                        if chars[j] == '\'' {
                            code.push('\'');
                            j += 1;
                            break;
                        }
                        code.push(' ');
                        j += 1;
                    }
                    i = j;
                    continue;
                }
                if is_plain {
                    code.push('\'');
                    code.push(' ');
                    code.push('\'');
                    i += 3;
                    continue;
                }
                // Lifetime (or stray quote): pass through.
                code.push('\'');
                i += 1;
                continue;
            }
            code.push(c);
            i += 1;
        }
        lines.push(LexedLine { line_no: idx + 1, code, allows, in_test: false });
    }
    mark_test_regions(&mut lines);
    lines
}

/// Mark every line belonging to a `#[cfg(test)]`-gated item.
///
/// A pending flag is raised when a skeleton contains the attribute
/// (including `#[cfg(all(test, …))]`); the region opens at the next `{`
/// and closes when the brace depth returns to its pre-region value.
/// Nested `#[cfg(test)]` inside an active region is subsumed.
fn mark_test_regions(lines: &mut [LexedLine]) {
    let mut depth: i64 = 0;
    let mut pending = false;
    // Brace depth the enclosing test region opened at, if any.
    let mut region_floor: Option<i64> = None;
    for line in lines.iter_mut() {
        if region_floor.is_none()
            && (line.code.contains("#[cfg(test)") || line.code.contains("#[cfg(all(test"))
        {
            pending = true;
        }
        if pending || region_floor.is_some() {
            line.in_test = true;
        }
        for ch in line.code.chars() {
            match ch {
                '{' => {
                    if pending && region_floor.is_none() {
                        region_floor = Some(depth);
                        pending = false;
                    }
                    depth += 1;
                }
                '}' => {
                    depth -= 1;
                    if let Some(floor) = region_floor {
                        if depth <= floor {
                            region_floor = None;
                        }
                    }
                }
                _ => {}
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn skeletons(src: &str) -> Vec<String> {
        lex(src).into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn line_comments_are_stripped() {
        let s = skeletons("let x = 1; // HashMap here\nlet y = 2;");
        assert!(!s[0].contains("HashMap"));
        assert!(s[0].contains("let x = 1;"));
        assert_eq!(s[1], "let y = 2;");
    }

    #[test]
    fn nested_block_comments_span_lines() {
        let src = "a(); /* outer /* inner */ still */ b();\n/* open\nstill comment\n*/ c();";
        let s = skeletons(src);
        assert!(s[0].contains("a();") && s[0].contains("b();"));
        assert!(!s[0].contains("inner"));
        assert!(!s[2].contains("still"));
        assert!(s[3].contains("c();"));
    }

    #[test]
    fn string_contents_are_blanked() {
        let s = skeletons(r#"let m = "HashMap::new()"; call();"#);
        assert!(!s[0].contains("HashMap"));
        assert!(s[0].contains("call();"));
        // The quotes themselves survive so columns stay meaningful.
        assert_eq!(s[0].matches('"').count(), 2);
    }

    #[test]
    fn escaped_quotes_do_not_close_strings() {
        let s = skeletons(r#"let m = "say \"Instant::now\" loud"; x();"#);
        assert!(!s[0].contains("Instant"));
        assert!(s[0].contains("x();"));
    }

    #[test]
    fn raw_strings_with_hashes() {
        let src = "let m = r#\"thread::spawn \" inner \"#; y();";
        let s = skeletons(src);
        assert!(!s[0].contains("thread::spawn"));
        assert!(s[0].contains("y();"));
    }

    #[test]
    fn char_literals_blank_but_lifetimes_survive() {
        let s = skeletons("fn f<'a>(x: &'a str) -> char { 'T' }");
        assert!(s[0].contains("<'a>"));
        assert!(s[0].contains("&'a str"));
        assert!(!s[0].contains("'T'"));
        let s = skeletons(r"let c = '\n'; let q = '\''; g();");
        assert!(s[0].contains("let q ="), "escaped char literal must close correctly");
        assert!(s[0].contains("g();"), "escaped-quote literal must not swallow the rest");
    }

    #[test]
    fn allow_markers_parse_with_justification() {
        let lines = lex("x(); // lint:allow(no-rogue-threads): service thread, joined at exit");
        assert_eq!(lines[0].allows.len(), 1);
        assert_eq!(lines[0].allows[0].rule, "no-rogue-threads");
        assert!(lines[0].allows[0].justified);
        let lines = lex("x(); // lint:allow(no-rogue-threads)");
        assert!(!lines[0].allows[0].justified, "bare allow must not count as justified");
    }

    #[test]
    fn cfg_test_regions_are_marked() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); }\n}\nfn after() {}";
        let lines = lex(src);
        assert!(!lines[0].in_test);
        assert!(lines[1].in_test, "the attribute line itself");
        assert!(lines[2].in_test);
        assert!(lines[3].in_test);
        assert!(lines[4].in_test, "closing brace still in region");
        assert!(!lines[5].in_test, "code after the region is live again");
    }

    #[test]
    fn cfg_test_in_string_does_not_open_a_region() {
        let src = "let s = \"#[cfg(test)]\";\nfn live() { y(); }";
        let lines = lex(src);
        assert!(!lines[1].in_test);
    }
}
