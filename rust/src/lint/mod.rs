//! `ppkm-lint` — a dependency-free static analyzer for the protocol
//! invariants nothing else enforces.
//!
//! The whole value of this reproduction rests on determinism contracts
//! that ordinary tests can only sample: transcripts are bit-identical
//! across duplex/TCP/two-process deployments, across `threads = 1` vs
//! `N`, and across `lanes = 1` vs `8`. A contributor who iterates a
//! `HashMap` in a share-producing path, reads the wall clock inside a
//! transcript-affecting loop, or spawns a thread outside
//! [`crate::runtime::pool`] breaks those contracts *silently* — the
//! seed of every such bug is a single token in the wrong module. This
//! module bans the tokens, by name, with module-path scoping:
//!
//! | rule | invariant |
//! |------|-----------|
//! | `no-unordered-iteration` | protocol state iterates deterministically |
//! | `no-wallclock-in-protocol` | share/reveal code never observes time |
//! | `no-rogue-threads` | all fan-out goes through `runtime::pool` |
//! | `no-unmetered-io` | every wire byte rides the [`crate::net::Meter`] |
//! | `no-ambient-entropy` | all randomness flows from the seeded PRG |
//! | `no-unchecked-open` | reveals outside the sanctioned semi-honest modules ride the MAC ledger |
//! | `no-panic-in-wire-paths` | wire-facing code returns typed errors |
//!
//! The pipeline is three small pieces: a comment/string-aware line
//! lexer ([`lexer`]) that produces *code skeletons* immune to
//! false positives from doc examples and string literals, a rule
//! engine ([`rules`]) matching scoped token sets against the
//! skeletons, and a policy file parser ([`config`]) that lets
//! `lint.rules` (repo root, scenario key=value format) re-scope any
//! rule without a recompile. Per-site escapes are spelled
//! `// lint:allow(rule-id): justification` — the justification is
//! mandatory, so every suppression documents itself.
//!
//! The `ppkm-lint` binary (`cargo run --release --bin ppkm-lint`)
//! walks `rust/src/**`, prints findings as `rule: file:line` and exits
//! non-zero on any finding; CI runs it as a blocking job. The rule
//! catalog and rationale live in `docs/STATIC_ANALYSIS.md`; the lint's
//! own regression suite (fixtures for hit/miss/suppression/
//! false-positive traps) is `rust/tests/lint.rs`.

pub mod config;
pub mod lexer;
pub mod rules;

pub use rules::{default_rules, Finding, Rule, Scope};

use crate::util::error::{Error, Result};
use std::path::{Path, PathBuf};

/// Map a crate-relative source path to its module path:
/// `src/offline/store.rs` → `offline::store`, `src/net/mod.rs` →
/// `net`, `src/lib.rs` → `` (crate root), `src/main.rs` → `main`,
/// `src/bin/ppkm-lint.rs` → `bin::ppkm_lint`.
pub fn module_path(rel: &str) -> String {
    let p = rel.strip_prefix("src/").unwrap_or(rel);
    let p = p.strip_suffix(".rs").unwrap_or(p);
    let p = p.strip_suffix("/mod").unwrap_or(p);
    if p == "lib" {
        return String::new();
    }
    p.replace('/', "::").replace('-', "_")
}

/// Lint one file's source text. `rel` is the crate-relative path used
/// in findings and for module scoping.
pub fn check_source(rules: &[Rule], rel: &str, source: &str) -> Vec<Finding> {
    let lines = lexer::lex(source);
    rules::check_lines(rules, rel, &module_path(rel), &lines)
}

/// Collect every `.rs` file under `dir`, in sorted (deterministic)
/// order, as paths relative to `crate_root`.
fn rust_files(crate_root: &Path, dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)
        .map_err(|e| Error::Config(format!("cannot read {}: {e}", dir.display())))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for path in entries {
        if path.is_dir() {
            rust_files(crate_root, &path, out)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            out.push(path.strip_prefix(crate_root).unwrap_or(&path).to_path_buf());
        }
    }
    Ok(())
}

/// Load the rule catalog, applying `<crate_root>/lint.rules` when it
/// exists (a missing policy file means built-in default scopes).
pub fn load_rules(crate_root: &Path) -> Result<Vec<Rule>> {
    let mut rules = default_rules();
    let policy = crate_root.join("lint.rules");
    if policy.exists() {
        let text = std::fs::read_to_string(&policy)
            .map_err(|e| Error::Config(format!("cannot read {}: {e}", policy.display())))?;
        config::apply(&text, &mut rules)?;
    }
    Ok(rules)
}

/// Lint every `.rs` file under `<crate_root>/src`, returning findings
/// in deterministic (path, rule, line) order.
pub fn scan_tree(crate_root: &Path, rules: &[Rule]) -> Result<Vec<Finding>> {
    let src = crate_root.join("src");
    let mut files = Vec::new();
    rust_files(crate_root, &src, &mut files)?;
    let mut findings = Vec::new();
    for rel in files {
        let path = crate_root.join(&rel);
        let source = std::fs::read_to_string(&path)
            .map_err(|e| Error::Config(format!("cannot read {}: {e}", path.display())))?;
        let rel_str = rel.to_string_lossy().replace('\\', "/");
        findings.extend(check_source(rules, &rel_str, &source));
    }
    findings.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    Ok(findings)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn module_paths_cover_the_crate_layout() {
        assert_eq!(module_path("src/offline/store.rs"), "offline::store");
        assert_eq!(module_path("src/net/mod.rs"), "net");
        assert_eq!(module_path("src/lib.rs"), "");
        assert_eq!(module_path("src/main.rs"), "main");
        assert_eq!(module_path("src/bin/ppkm-lint.rs"), "bin::ppkm_lint");
        assert_eq!(module_path("src/lint/lexer.rs"), "lint::lexer");
    }

    #[test]
    fn check_source_ties_the_pipeline_together() {
        let src = "use std::collections::HashMap;\n";
        let f = check_source(&default_rules(), "src/ss/share.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "no-unordered-iteration");
        // The same text outside the banned subtrees is clean.
        assert!(check_source(&default_rules(), "src/cli.rs", src).is_empty());
    }

    #[test]
    fn the_live_tree_is_clean() {
        // The acceptance gate, as a unit test: zero findings over this
        // repo's own src/ with the shipped policy file applied.
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let rules = load_rules(root).unwrap();
        let findings = scan_tree(root, &rules).unwrap();
        assert!(
            findings.is_empty(),
            "ppkm-lint found {} violation(s):\n{}",
            findings.len(),
            findings.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("\n")
        );
    }
}
