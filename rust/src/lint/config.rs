//! `lint.rules` — scope policy for the rule catalog, in the repo's
//! scenario key=value format.
//!
//! Rules are code ([`super::rules::default_rules`]); *scopes* are
//! policy, and policy belongs in a reviewable text file at the repo
//! root rather than in a recompile. The format is the same line-based
//! `key = value` layout the deployment scenarios use (`scenarios/*.scn`,
//! parsed by `coordinator::remote`): `#` starts a comment, blank lines
//! are ignored, and every key names the rule it re-scopes:
//!
//! ```text
//! # Where HashMap/HashSet are banned.
//! scope.no-unordered-iteration = ss offline kmeans mkmeans serve net runtime
//! # Where wall-clock reads are allowed.
//! allow.no-wallclock-in-protocol = util::timer net::shape offline::timed bench main
//! # Subtree escape hatch (use sparingly; prefer inline lint:allow).
//! exempt.no-rogue-threads =
//! ```
//!
//! * `scope.<rule>` **replaces** the banned-subtree list of a
//!   [`Scope::BannedIn`] rule;
//! * `allow.<rule>` **replaces** the allowed-subtree list of a
//!   [`Scope::ConfinedTo`] rule;
//! * `exempt.<rule>` appends exempted subtrees to any rule.
//!
//! Values are whitespace-separated module-path prefixes (`offline`
//! covers `offline::store`). Mismatched key kinds, unknown keys and
//! unknown rule ids are hard errors — a typo must fail the lint run,
//! not silently widen a scope.

use super::rules::{Rule, Scope};
use crate::util::error::{Error, Result};

/// Parse a `lint.rules` document and apply it to the rule catalog.
///
/// `rules` is mutated in place; the function is total — either every
/// line applies or a typed [`Error::Config`] names the offending line.
pub fn apply(text: &str, rules: &mut [Rule]) -> Result<()> {
    for (idx, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let lno = idx + 1;
        let Some((key, value)) = line.split_once('=') else {
            return Err(Error::Config(format!(
                "lint.rules:{lno}: expected `key = value`, got `{line}`"
            )));
        };
        let key = key.trim();
        let mods: Vec<String> = value.split_whitespace().map(|s| s.to_string()).collect();
        let Some((kind, rule_id)) = key.split_once('.') else {
            return Err(Error::Config(format!(
                "lint.rules:{lno}: key `{key}` is not `scope.<rule>`, `allow.<rule>` \
                 or `exempt.<rule>`"
            )));
        };
        let Some(rule) = rules.iter_mut().find(|r| r.id == rule_id) else {
            return Err(Error::Config(format!(
                "lint.rules:{lno}: unknown rule `{rule_id}`"
            )));
        };
        match (kind, &mut rule.scope) {
            ("scope", Scope::BannedIn(list)) => *list = mods,
            ("allow", Scope::ConfinedTo(list)) => *list = mods,
            ("exempt", _) => rule.exempt.extend(mods),
            ("scope", Scope::ConfinedTo(_)) => {
                return Err(Error::Config(format!(
                    "lint.rules:{lno}: `{rule_id}` is a confined rule — use \
                     `allow.{rule_id}` to list the permitted subtrees"
                )))
            }
            ("allow", Scope::BannedIn(_)) => {
                return Err(Error::Config(format!(
                    "lint.rules:{lno}: `{rule_id}` is a banned-in rule — use \
                     `scope.{rule_id}` to list the banned subtrees"
                )))
            }
            (other, _) => {
                return Err(Error::Config(format!(
                    "lint.rules:{lno}: unknown directive `{other}.` (want scope/allow/exempt)"
                )))
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::rules::default_rules;

    #[test]
    fn rescopes_banned_and_confined_rules() {
        let mut rules = default_rules();
        let text = "# comment\n\nscope.no-unordered-iteration = ss net\n\
                    allow.no-wallclock-in-protocol = util::timer\n\
                    exempt.no-rogue-threads = mkmeans::legacy\n";
        apply(text, &mut rules).unwrap();
        let r = rules.iter().find(|r| r.id == "no-unordered-iteration").unwrap();
        assert_eq!(r.scope, Scope::BannedIn(vec!["ss".into(), "net".into()]));
        let w = rules.iter().find(|r| r.id == "no-wallclock-in-protocol").unwrap();
        assert_eq!(w.scope, Scope::ConfinedTo(vec!["util::timer".into()]));
        let t = rules.iter().find(|r| r.id == "no-rogue-threads").unwrap();
        assert_eq!(t.exempt, vec!["mkmeans::legacy".to_string()]);
    }

    #[test]
    fn typos_are_hard_errors() {
        let mut rules = default_rules();
        assert!(apply("scope.no-such-rule = net", &mut rules).is_err());
        assert!(apply("banish.no-rogue-threads = net", &mut rules).is_err());
        assert!(apply("no equals sign here", &mut rules).is_err());
        // Kind mismatch: confined rules take `allow`, not `scope`.
        assert!(apply("scope.no-rogue-threads = runtime::pool", &mut rules).is_err());
        assert!(apply("allow.no-panic-in-wire-paths = net", &mut rules).is_err());
    }

    #[test]
    fn empty_value_clears_a_list() {
        let mut rules = default_rules();
        apply("allow.no-wallclock-in-protocol =", &mut rules).unwrap();
        let w = rules.iter().find(|r| r.id == "no-wallclock-in-protocol").unwrap();
        assert_eq!(w.scope, Scope::ConfinedTo(vec![]));
    }
}
