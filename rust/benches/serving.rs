//! Scoring-service study: train once, then measure steady-state serving
//! latency/throughput per micro-batch under the LAN and WAN link models,
//! plus the material-bank ledger.
//!
//! The claims under test (regression-tested in `rust/tests/serve.rs`):
//!
//! * every scored batch costs **exactly** the assignment-only budget
//!   `score_rounds(k) = 1 + ⌈log₂k⌉·(CMP_ROUNDS+1) + CMP_ROUNDS + 1`
//!   flights — no S3 rounds ever;
//! * the per-batch offline demand is uniform, so a bank prefabricated
//!   from one probe batch serves the whole stream with zero misses;
//! * bank stock accounting balances exactly across replenishments.
//!
//! Emits `BENCH_serving.json` for the tracking harness.

use ppkmeans::bench::{fmt_bytes, Table};
use ppkmeans::coordinator::serve::{serving_bench_json, ServeReport};
use ppkmeans::data::fraud_gen;
use ppkmeans::kmeans::config::{Partition, SecureKmeansConfig};
use ppkmeans::net::cost::CostModel;
use ppkmeans::offline::bank::BankConfig;
use ppkmeans::serve::driver::{serve_stream, train_model, ServeConfig};
use ppkmeans::serve::scorer::score_rounds;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let (n_train, k, iters) = if full { (10_000, 4, 8) } else { (1_000, 4, 4) };
    let (batch, batches) = if full { (256, 24) } else { (64, 12) };
    let bank = BankConfig { prefab_batches: 4, low_water: 2, refill_batches: 4 };

    println!("training: n={n_train} k={k} t={iters} (fraud 18+24 vertical split)");
    let f = fraud_gen::generate(n_train, 0.05, 77);
    let cfg = SecureKmeansConfig {
        k,
        iters,
        partition: Partition::Vertical { d_a: f.d_payment },
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let (_, models) = train_model(&f.data, &cfg, 0.05).expect("train");
    let train_secs = t0.elapsed().as_secs_f64();
    println!("  trained in {train_secs:.2}s; serving {batches} batches × {batch} tx\n");

    let stream = fraud_gen::generate(batches * batch, 0.05, 4242);
    let scfg =
        ServeConfig { batch_rows: batch, batches, bank, seed: 0xBE4C4, ..Default::default() };
    let out = serve_stream(models, &stream.data, &scfg).expect("serve");
    let lan = ServeReport::from_serve(&out, &CostModel::lan());
    let wan = ServeReport::from_serve(&out, &CostModel::wan());

    let mut tbl = Table::new(
        &format!("Scoring service — k={k}, batch={batch}, {batches} batches (first = probe)"),
        &["link", "mean lat/batch", "max lat/batch", "throughput", "bytes/batch", "rounds/batch"],
    );
    for (label, r) in [("LAN", &lan), ("WAN", &wan)] {
        tbl.row(vec![
            label.to_string(),
            format!("{:.3} ms", r.mean_latency_secs * 1e3),
            format!("{:.3} ms", r.max_latency_secs * 1e3),
            format!("{:.0} tx/s", r.throughput_rows_per_sec),
            fmt_bytes(r.bytes_per_batch),
            format!("{}", r.rounds_per_batch),
        ]);
    }
    tbl.print();
    println!(
        "\nbank: prefabricated {} + replenished {} − consumed {} = {} in stock \
         ({} replenishment(s), {} misses, {}/batch mat triples)",
        out.bank_prefabricated,
        out.bank_replenished,
        out.bank_consumed,
        out.bank_remaining,
        out.bank_replenish_events,
        out.bank_misses,
        fmt_bytes(out.per_batch_mat_triple_bytes),
    );

    // Shape checks the table should witness.
    assert_eq!(lan.rounds_per_batch, score_rounds(k), "assignment-only budget");
    assert!(
        out.batch_stats.iter().all(|b| b.online.rounds == score_rounds(k)),
        "every batch must cost the exact budget"
    );
    assert_eq!(out.bank_misses, 0, "prefabricated stock must cover every draw");
    assert_eq!(
        out.bank_prefabricated + out.bank_replenished - out.bank_consumed,
        out.bank_remaining,
        "bank ledger must balance"
    );
    assert!(out.bank_replenish_events >= 1, "the stream must outrun the prefab stock");

    let json = serving_bench_json(&out, &lan, &wan, train_secs);
    std::fs::write("BENCH_serving.json", &json).expect("write BENCH_serving.json");
    println!("\nwrote BENCH_serving.json");
}
