//! Table 1 — running time vs M-Kmeans on synthetic data (LAN, d = 2,
//! t = 10, l = 64).
//!
//! Paper grid: n ∈ {10^4, 10^5}, k ∈ {2, 5}. Default run scales n ÷ 10
//! (pass `--full` after `--` for paper sizes) and caps the measured
//! M-Kmeans instance at `MK_CAP` samples, extrapolating linearly (its
//! per-sample cost is linear: inline OT + per-sample GC — documented in
//! EXPERIMENTS.md). Reported time = measured compute + modeled LAN link
//! time from exact byte/round counts.
//!
//! Paper reference rows (minutes): (10^4,2): 0.33/1.61/1.94 vs 1.92;
//! (10^4,5): 0.94/4.70/5.64 vs 5.81; (10^5,2): 3.12/15.19/18.31 vs
//! 18.02; (10^5,5): 9.06/48.39/57.45 vs 58.09.

use ppkmeans::bench::{fmt_secs, Table};
use ppkmeans::coordinator::Report;
use ppkmeans::data::blobs::BlobSpec;
use ppkmeans::kmeans::config::{Partition, SecureKmeansConfig};
use ppkmeans::kmeans::secure;
use ppkmeans::mkmeans::{self, MkmeansConfig};
use ppkmeans::net::cost::CostModel;
use ppkmeans::offline::pricing;

/// Largest M-Kmeans instance actually executed (rest extrapolated).
const MK_CAP: usize = 1_000;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let ns: &[usize] = if full { &[10_000, 100_000] } else { &[1_000, 4_000] };
    let ks = [2usize, 5];
    let (d, iters) = (2usize, 10usize);
    let lan = CostModel::lan();

    println!("calibrating OT generator...");
    let cal = pricing::calibrate();
    println!(
        "  {:.2} us/OT, {:.2} us/bit-lane, setup {:.2}s",
        cal.secs_per_ot * 1e6,
        cal.secs_per_bit_lane * 1e6,
        cal.setup_secs
    );

    let mut table = Table::new(
        "Table 1 — running time (LAN, d=2, t=10, l=64)",
        &["n", "k", "ours online", "ours offline", "ours total", "M-Kmeans"],
    );

    for &n in ns {
        for &k in &ks {
            let ds = BlobSpec::new(n, d, k).generate(1);
            let cfg = SecureKmeansConfig {
                k,
                iters,
                partition: Partition::Vertical { d_a: 1 },
                ..Default::default()
            };
            let out = secure::run(&ds, &cfg).expect("ours");
            let report = Report::from_run(&out, &lan, &cal);

            // M-Kmeans: measured at min(n, MK_CAP), linear extrapolation.
            let mk_n = n.min(MK_CAP);
            let mk_ds = BlobSpec::new(mk_n, d, k).generate(1);
            let mcfg = MkmeansConfig { k, iters, seed: cfg.seed, d_a: 1 };
            let mk = mkmeans::run_vertical(&mk_ds, &mcfg).expect("mkmeans");
            let scale = n as f64 / mk_n as f64;
            let mk_time =
                (mk.wall_secs + lan.time_raw(mk.bytes_total / 2, mk.rounds)) * scale;

            table.row(vec![
                format!("{n}"),
                format!("{k}"),
                fmt_secs(report.online_secs),
                fmt_secs(report.offline_secs),
                fmt_secs(report.total_secs()),
                format!(
                    "{}{}",
                    fmt_secs(mk_time),
                    if mk_n < n { "*" } else { "" }
                ),
            ]);
        }
    }
    table.print();
    println!("\n(*) M-Kmeans measured at n={MK_CAP} and scaled linearly (cost ∝ n).");
    println!("shape checks: ours-online ≪ M-Kmeans; ours-total ≈ M-Kmeans (same order).");
}
