//! Table 1 — running time vs M-Kmeans on synthetic data (LAN, d = 2,
//! t = 10, l = 64).
//!
//! Paper grid: n ∈ {10^4, 10^5}, k ∈ {2, 5}. Default run scales n ÷ 10
//! (pass `--full` after `--` for paper sizes, `--smoke` for the CI
//! quick mode) and caps the measured M-Kmeans instance at `MK_CAP`
//! samples, extrapolating linearly (its per-sample cost is linear:
//! inline OT + per-sample GC — documented in EXPERIMENTS.md). Reported
//! time = measured compute + modeled LAN link time from exact
//! byte/round counts.
//!
//! **Measured link time.** Alongside the modeled figures, rows up to
//! `MEASURE_CAP` samples are re-run with a deterministic link shaper
//! (`net::shape`) enforcing the paper's LAN and WAN models on the
//! loopback transport: the reported wall-clock then *measures* compute
//! + RTT per flight + bandwidth pacing per byte. Both appear in
//! `BENCH_table1_runtime.json` so modeled and measured numbers can be
//! compared directly; above the cap the shaped-WAN run would take hours
//! (the link model says so) and the measured fields are `null`.
//!
//! **Malicious column.** `malicious Δt` is the modeled LAN cost of the
//! malicious tier's surcharge (MAC barriers + commit-reveal), measured
//! once at a small size — the surcharge is O(1) per phase boundary,
//! independent of n/d/k, which the bench goldens pin.
//!
//! Paper reference rows (minutes): (10^4,2): 0.33/1.61/1.94 vs 1.92;
//! (10^4,5): 0.94/4.70/5.64 vs 5.81; (10^5,2): 3.12/15.19/18.31 vs
//! 18.02; (10^5,5): 9.06/48.39/57.45 vs 58.09.

use ppkmeans::bench::{fmt_secs, train_malicious_counts, Table};
use ppkmeans::coordinator::Report;
use ppkmeans::data::blobs::{BlobSpec, Dataset};
use ppkmeans::kmeans::config::{Partition, SecureKmeansConfig};
use ppkmeans::kmeans::secure;
use ppkmeans::mkmeans::{self, MkmeansConfig};
use ppkmeans::net::cost::CostModel;
use ppkmeans::offline::pricing::{self, OtCalibration};

/// Largest M-Kmeans instance actually executed (rest extrapolated).
const MK_CAP: usize = 1_000;

/// Largest instance measured under the shaped links (the shaped-WAN run
/// above this would take hours, as the model itself predicts).
const MEASURE_CAP: usize = 4_000;

/// Wall-clock of a full run with the transport shaped to `link`.
fn shaped_wall(ds: &Dataset, cfg: &SecureKmeansConfig, link: CostModel) -> f64 {
    let mut cfg = cfg.clone();
    cfg.shape = Some(link);
    secure::run(ds, &cfg).expect("shaped run").wall_secs
}

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let smoke = std::env::args().any(|a| a == "--smoke");
    let ns: &[usize] = if full {
        &[10_000, 100_000]
    } else if smoke {
        &[256]
    } else {
        &[1_000, 4_000]
    };
    let ks = [2usize, 5];
    let d = 2usize;
    let iters = if smoke { 3 } else { 10 };
    let lan = CostModel::lan();
    let wan = CostModel::wan();

    let cal = if smoke {
        // Fixed calibration keeps the CI quick mode fast; wall-clock is
        // informational there anyway (counts are what the goldens pin).
        OtCalibration { secs_per_ot: 1e-5, secs_per_bit_lane: 1e-6, setup_secs: 0.5 }
    } else {
        println!("calibrating OT generator...");
        let cal = pricing::calibrate();
        println!(
            "  {:.2} us/OT, {:.2} us/bit-lane, setup {:.2}s",
            cal.secs_per_ot * 1e6,
            cal.secs_per_bit_lane * 1e6,
            cal.setup_secs
        );
        cal
    };

    // The malicious tier's surcharge is O(1) per phase boundary —
    // independent of n, d and k (pinned by the bench goldens) — so one
    // small measured run prices the column for every row.
    let mc = train_malicious_counts(256, d, 2, iters);
    let mal_lan = lan.time_raw(mc.extra_bytes() / 2, mc.extra_rounds());
    let mal_wan = wan.time_raw(mc.extra_bytes() / 2, mc.extra_rounds());

    let mut table = Table::new(
        "Table 1 — running time (LAN, d=2, t=10, l=64)",
        &[
            "n",
            "k",
            "ours online",
            "ours offline",
            "ours total",
            "malicious Δt",
            "measured LAN",
            "M-Kmeans",
        ],
    );
    let mut rows_json: Vec<String> = Vec::new();

    for &n in ns {
        for &k in &ks {
            let ds = BlobSpec::new(n, d, k).generate(1);
            let cfg = SecureKmeansConfig {
                k,
                iters,
                partition: Partition::Vertical { d_a: 1 },
                ..Default::default()
            };
            let out = secure::run(&ds, &cfg).expect("ours");
            let report = Report::from_run(&out, &lan, &cal);
            let report_wan = Report::from_run(&out, &wan, &cal);

            // Measured: the same protocol with the loopback transport
            // shaped to each link (RTT per flight + bandwidth pacing).
            let (m_lan, m_wan) = if n <= MEASURE_CAP {
                (Some(shaped_wall(&ds, &cfg, lan)), Some(shaped_wall(&ds, &cfg, wan)))
            } else {
                (None, None)
            };

            // M-Kmeans: measured at min(n, MK_CAP), linear extrapolation
            // (skipped in the CI quick mode).
            let mk_time = if smoke {
                None
            } else {
                let mk_n = n.min(MK_CAP);
                let mk_ds = BlobSpec::new(mk_n, d, k).generate(1);
                let mcfg = MkmeansConfig { k, iters, seed: cfg.seed, d_a: 1 };
                let mk = mkmeans::run_vertical(&mk_ds, &mcfg).expect("mkmeans");
                let scale = n as f64 / mk_n as f64;
                Some((mk.wall_secs + lan.time_raw(mk.bytes_total / 2, mk.rounds)) * scale)
            };

            // Both parties summed, matching BENCH_table2_comm.json's
            // field of the same name; flights are party 0's.
            let online_bytes = out.meter_a.total_prefix("online.").bytes_sent
                + out.meter_b.total_prefix("online.").bytes_sent;
            let online_rounds = out.meter_a.total_prefix("online.").rounds;
            table.row(vec![
                format!("{n}"),
                format!("{k}"),
                fmt_secs(report.online_secs),
                fmt_secs(report.offline_secs),
                fmt_secs(report.total_secs()),
                format!("+{}", fmt_secs(mal_lan)),
                m_lan.map(fmt_secs).unwrap_or_else(|| "-".into()),
                mk_time.map(fmt_secs).unwrap_or_else(|| "-".into()),
            ]);
            let opt = |v: Option<f64>| {
                v.map(|x| format!("{x:.6}")).unwrap_or_else(|| "null".into())
            };
            rows_json.push(format!(
                "    {{\"n\": {n}, \"k\": {k}, \"iters\": {iters}, \
                 \"online_bytes\": {}, \"online_rounds\": {}, \
                 \"modeled\": {{\"lan_online_secs\": {:.6}, \"wan_online_secs\": {:.6}, \
                 \"offline_secs\": {:.6}}}, \
                 \"measured\": {{\"lan_wall_secs\": {}, \"wan_wall_secs\": {}}}, \
                 \"malicious\": {{\"extra_bytes\": {}, \"extra_rounds\": {}, \
                 \"lan_extra_secs\": {mal_lan:.6}, \"wan_extra_secs\": {mal_wan:.6}}}, \
                 \"mkmeans_lan_secs\": {}}}",
                online_bytes,
                online_rounds,
                report.online_secs,
                report_wan.online_secs,
                report.offline_secs,
                opt(m_lan),
                opt(m_wan),
                mc.extra_bytes(),
                mc.extra_rounds(),
                opt(mk_time),
            ));
        }
    }
    table.print();
    if !smoke {
        println!("\n(*) M-Kmeans measured at n={MK_CAP} and scaled linearly (cost ∝ n).");
    }
    println!("shape checks: ours-online ≪ M-Kmeans; measured LAN ≈ modeled LAN online.");

    let mode = if full {
        "full"
    } else if smoke {
        "smoke"
    } else {
        "default"
    };
    let json = format!(
        "{{\n  \"bench\": \"table1_runtime\",\n  \"mode\": \"{mode}\",\n  \"rows\": [\n{}\n  ]\n}}\n",
        rows_json.join(",\n")
    );
    match std::fs::write("BENCH_table1_runtime.json", &json) {
        Ok(()) => println!("wrote BENCH_table1_runtime.json"),
        Err(e) => eprintln!("could not write BENCH_table1_runtime.json: {e}"),
    }
}
