//! Figure 4 — effectiveness of the sparse optimization (WAN).
//!
//! The paper's regime is **bandwidth-bound** (n up to 5·10^6 over a
//! 20 Mbps WAN), so its curves are dominated by link time. We therefore
//! report the two components separately from exact measurements:
//! *link* = modeled WAN time from the measured S1 bytes/rounds (the
//! paper's dominant term, exact at any n), and *compute* = measured S1
//! wall-clock on this host (HE work ∝ nnz — the sparsity lever).
//!
//! (a) dimension sweep at sparsity 0.2 (paper: n = 10^6, k = 2):
//!     dense link time grows ∝ n·d; sparse link time is k·(d+n)
//!     ciphertexts — a much smaller slope in d.
//! (b) sparsity sweep × sample size: sparse compute falls as sparsity
//!     rises, and the dense-vs-sparse gap widens with n.

use ppkmeans::bench::{fmt_secs, Table};
use ppkmeans::data::sparse_gen;
use ppkmeans::kmeans::config::{EsdMode, Partition, SecureKmeansConfig};
use ppkmeans::kmeans::secure;
use ppkmeans::net::cost::CostModel;

/// Measured S1 (link_secs, compute_secs) per run.
fn s1_cost(n: usize, d: usize, sparsity: f64, sparse: bool, iters: usize, wan: &CostModel) -> (f64, f64) {
    let ds = sparse_gen::generate(n, d, 2, sparsity, 9);
    let cfg = SecureKmeansConfig {
        k: 2,
        iters,
        esd: if sparse { EsdMode::He { bits: 768 } } else { EsdMode::Vectorized },
        partition: Partition::Vertical { d_a: d / 2 },
        ..Default::default()
    };
    let out = secure::run(&ds, &cfg).expect("run");
    let bytes = out.meter_a.get("online.s1").bytes_sent + out.meter_b.get("online.s1").bytes_sent;
    let rounds = out.meter_a.get("online.s1").rounds;
    (wan.time_raw(bytes / 2, rounds), out.step_wall.s1_distance)
}

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let wan = CostModel::wan();
    let n_a = if full { 20_000 } else { 1_500 };
    let iters = 2;

    // ---- Panel (a): dimension sweep at sparsity 0.2.
    let mut ta = Table::new(
        &format!("Fig 4(a) — S1 online cost vs d (sparsity 0.2, n={n_a}, k=2, t={iters})"),
        &["d", "dense link(WAN)", "sparse link(WAN)", "dense compute", "sparse compute"],
    );
    for d in [8usize, 16, 32] {
        let (dl, dc) = s1_cost(n_a, d, 0.2, false, iters, &wan);
        let (sl, sc) = s1_cost(n_a, d, 0.2, true, iters, &wan);
        ta.row(vec![
            format!("{d}"),
            fmt_secs(dl),
            fmt_secs(sl),
            fmt_secs(dc),
            fmt_secs(sc),
        ]);
    }
    ta.print();
    println!("shape check: dense link time grows ∝ n·d; the sparse slope in d is");
    println!("far smaller (k·d ciphertexts) — the paper's bandwidth-bound win.\n");

    // ---- Panel (b): sparsity × sample-size sweep (compute is the lever).
    let ns: &[usize] = if full { &[10_000, 20_000, 40_000] } else { &[1_000, 2_000, 4_000] };
    let d = 32;
    let mut tb = Table::new(
        &format!("Fig 4(b) — S1 sparse-path compute vs sparsity (d={d}, k=2, t={iters})"),
        &["n", "dense ref", "s=0.0", "s=0.5", "s=0.9", "s=0.99", "gain 0→.99"],
    );
    for &n in ns {
        let mut row = vec![format!("{n}")];
        let (_, dc) = s1_cost(n, d, 0.5, false, iters, &wan);
        row.push(fmt_secs(dc));
        let mut first = 0.0;
        let mut last = 0.0;
        for s in [0.0, 0.5, 0.9, 0.99] {
            let (_, sc) = s1_cost(n, d, s, true, iters, &wan);
            if s == 0.0 {
                first = sc;
            }
            last = sc;
            row.push(fmt_secs(sc));
        }
        row.push(format!("{:.2}x", first / last.max(1e-9)));
        tb.row(row);
    }
    tb.print();
    println!("shape check: sparse-path compute falls with sparsity (HE work ∝ nnz),");
    println!("and the absolute improvement widens as n grows (paper Q4).");
}
