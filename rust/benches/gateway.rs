//! Gateway study: many concurrent scoring sessions multiplexed over one
//! party-pair link, with the sharded background-replenished material
//! bank. Sweeps the session count under loopback-LAN and WAN link
//! reporting and emits `BENCH_gateway.json` (throughput + p50/p99
//! session latency per sweep point).
//!
//! The claims under test (regression-tested in `rust/tests/gateway.rs`):
//!
//! * **determinism** — a session's reveals and per-session meter are
//!   bit-identical whether it runs alone (`sessions = 1`) or among `N`
//!   concurrent sessions;
//! * **meter conservation** — per-session meters sum exactly to the
//!   link's `gateway.mux` totals;
//! * **sparsity of stalls** — at steady state the background
//!   replenishers keep the scoring path at **zero** bank misses, and
//!   the sharded ledger balances exactly.
//!
//! `--full` widens the sweep to 64/256 sessions and adds a shaped-WAN
//! point at 8 sessions (real pacing, minutes of wall-clock); the
//! default/`--smoke` run keeps CI-sized points. Shaped-WAN at 64/256
//! sessions would be hours of paced sleeps, so high session counts are
//! reported under the modeled WAN link (`wan-model`) instead — same
//! bytes and flights, link time from [`CostModel::time_raw`].

use ppkmeans::bench::{fmt_bytes, Table};
use ppkmeans::coordinator::serve::{gateway_bench_json, GatewayReport};
use ppkmeans::data::fraud_gen;
use ppkmeans::kmeans::config::{Partition, SecureKmeansConfig};
use ppkmeans::net::cost::CostModel;
use ppkmeans::net::mux::MUX_LINK_PHASE;
use ppkmeans::offline::bank::BankConfig;
use ppkmeans::serve::driver::train_model;
use ppkmeans::serve::gateway::{gateway_stream, GatewayConfig, GatewayOutput, SessionReport};
use ppkmeans::serve::model::TrainedModel;

fn config(sessions: usize, batch_rows: usize, batches: usize, shape: Option<CostModel>) -> GatewayConfig {
    GatewayConfig {
        sessions,
        queue: 0,
        workers: 4,
        replenishers: 1,
        shards: 2,
        batch_rows,
        batches,
        bank: BankConfig { prefab_batches: 2, low_water: 2, refill_batches: 2 },
        seed: 0x6A7E1,
        shape,
        ..GatewayConfig::default()
    }
}

/// Run one sweep point and return (party-0 output, mux link bytes)
/// after checking the invariants every point must hold.
fn run_point(models: &[TrainedModel; 2], cfg: &GatewayConfig) -> (GatewayOutput, u64) {
    let rows = cfg.sessions * cfg.batches * cfg.batch_rows;
    let stream = fraud_gen::generate(rows, 0.05, 31_415);
    let out = gateway_stream([models[0].clone(), models[1].clone()], &stream.data, cfg)
        .expect("gateway run");
    assert_eq!(out.a.admitted(), cfg.sessions, "queue 0 admits everything");
    assert!(out.a.rejected.is_empty());
    assert_eq!(out.a.misses(), 0, "prefab + background refill must cover every draw");
    assert!(out.a.ledger.balances(), "sharded bank ledger must balance: {:?}", out.a.ledger);
    // Per-session meters must sum exactly to the link's mux phase.
    let sum = out.a.online_total();
    let link = out.meter_a.get(MUX_LINK_PHASE);
    assert_eq!(sum.bytes_sent, link.bytes_sent, "session meters must sum to the link");
    assert_eq!(sum.msgs_sent, link.msgs_sent);
    (out.a, link.bytes_sent)
}

/// Session 1's report out of a run (tag 1 exists at every sweep point).
fn first_session(out: &GatewayOutput) -> SessionReport {
    out.sessions
        .iter()
        .find(|(tag, _)| *tag == 1)
        .and_then(|(_, r)| r.as_ref().ok())
        .expect("session 1 succeeded")
        .clone()
}

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let (n_train, k, iters) = if full { (10_000, 4, 8) } else { (1_000, 4, 4) };
    let (batch, batches) = if full { (32, 8) } else { (16, 6) };
    let lan_sessions: &[usize] = if full { &[1, 8, 64, 256] } else { &[1, 8] };
    let wan_shaped_sessions: &[usize] = if full { &[8] } else { &[1] };

    println!("training: n={n_train} k={k} t={iters} (fraud 18+24 vertical split)");
    let f = fraud_gen::generate(n_train, 0.05, 77);
    let tcfg = SecureKmeansConfig {
        k,
        iters,
        partition: Partition::Vertical { d_a: f.d_payment },
        ..Default::default()
    };
    let (_, models) = train_model(&f.data, &tcfg, 0.05).expect("train");
    println!("  trained; gateway sweep: {batches} batches × {batch} tx per session\n");

    let mut tbl = Table::new(
        &format!("Gateway — k={k}, batch={batch}, {batches} batches/session"),
        &["link", "sessions", "throughput", "p50 lat", "p99 lat", "link bytes"],
    );
    let mut sweeps: Vec<(String, usize, GatewayReport)> = Vec::new();
    let mut row = |tbl: &mut Table, label: &str, sessions: usize, r: &GatewayReport, bytes: u64| {
        tbl.row(vec![
            label.to_string(),
            sessions.to_string(),
            format!("{:.0} tx/s", r.throughput_rows_per_sec),
            format!("{:.3} ms", r.p50_latency_secs * 1e3),
            format!("{:.3} ms", r.p99_latency_secs * 1e3),
            fmt_bytes(bytes),
        ]);
    };

    // Loopback sweep, reported under both link models; remember every
    // session-1 transcript for the determinism check below.
    let mut session1: Vec<SessionReport> = Vec::new();
    for &s in lan_sessions {
        let cfg = config(s, batch, batches, None);
        let (out, bytes) = run_point(&models, &cfg);
        let lan = GatewayReport::from_gateway(&out, cfg.batch_rows, &CostModel::lan());
        let wan = GatewayReport::from_gateway(&out, cfg.batch_rows, &CostModel::wan());
        row(&mut tbl, "lan", s, &lan, bytes);
        row(&mut tbl, "wan-model", s, &wan, bytes);
        sweeps.push(("lan".into(), s, lan));
        sweeps.push(("wan-model".into(), s, wan));
        session1.push(first_session(&out));
    }
    // Shaped WAN: the transport really paces RTT + bandwidth, so the
    // measured wall-clock is the link (kept to CI-sized session counts).
    for &s in wan_shaped_sessions {
        let cfg = config(s, batch, batches, Some(CostModel::wan()));
        let (out, bytes) = run_point(&models, &cfg);
        let wan = GatewayReport::from_gateway(&out, cfg.batch_rows, &CostModel::wan());
        row(&mut tbl, "wan-shaped", s, &wan, bytes);
        sweeps.push(("wan-shaped".into(), s, wan));
    }
    tbl.print();

    // Determinism: session 1 (same tag, same rows, same seeds) must be
    // bit-identical at every concurrency level of the loopback sweep.
    let base = &session1[0];
    for (i, r) in session1.iter().enumerate().skip(1) {
        assert_eq!(r.results, base.results, "sessions={} changed session 1's reveals", lan_sessions[i]);
        assert_eq!(r.online, base.online, "sessions={} changed session 1's meter", lan_sessions[i]);
        assert_eq!(r.misses, 0);
    }
    println!(
        "\nsession 1 is bit-identical across sessions ∈ {lan_sessions:?} \
         ({} B online, {} flights)",
        base.online.bytes_sent, base.online.rounds
    );

    let json = gateway_bench_json(k, batch, batches, &sweeps);
    std::fs::write("BENCH_gateway.json", &json).expect("write BENCH_gateway.json");
    println!("wrote BENCH_gateway.json");
}
