//! Figure 2 — online vs offline cost per protocol step (WAN;
//! n = 1000, d = 2, k = 4, t = 20).
//!
//! Reproduces both panels: per-step running time and per-step
//! communication, splitting S1 (distance) / S2 (assignment) /
//! S3 (update) into their data-dependent online part and the
//! data-independent offline (triple generation) part attributed by the
//! per-step demand recording.
//!
//! Expected shape (paper): offline ≫ online in every step; S2 dominates
//! online rounds (comparison tree), S1/S3 dominate offline volume
//! (matrix triples).

use ppkmeans::bench::{fmt_bytes, fmt_secs, Table};
use ppkmeans::coordinator::Report;
use ppkmeans::data::blobs::BlobSpec;
use ppkmeans::kmeans::config::{Partition, SecureKmeansConfig};
use ppkmeans::kmeans::secure;
use ppkmeans::net::cost::CostModel;
use ppkmeans::offline::pricing;

fn main() {
    let (n, d, k, iters) = (1000usize, 2usize, 4usize, 20usize);
    let wan = CostModel::wan();
    println!("calibrating OT generator...");
    let cal = pricing::calibrate();

    let ds = BlobSpec::new(n, d, k).generate(2);
    let cfg = SecureKmeansConfig {
        k,
        iters,
        partition: Partition::Vertical { d_a: 1 },
        ..Default::default()
    };
    let out = secure::run(&ds, &cfg).expect("run");
    let report = Report::from_run(&out, &wan, &cal);

    let mut time_tbl = Table::new(
        "Fig 2 (left) — running time per step (WAN, n=1000, d=2, k=4, t=20)",
        &["step", "online", "offline", "off/on ratio"],
    );
    let mut comm_tbl = Table::new(
        "Fig 2 (right) — communication per step (both parties)",
        &["step", "online", "offline", "off/on ratio"],
    );
    let names = ["S1 distance", "S2 assignment", "S3 update"];
    for i in 0..3 {
        let off_secs = pricing::offline_secs(&out.step_demands[i], &cal);
        let off_bytes = pricing::offline_bytes(&out.step_demands[i]);
        time_tbl.row(vec![
            names[i].into(),
            fmt_secs(report.steps[i]),
            fmt_secs(off_secs),
            format!("{:.1}x", off_secs / report.steps[i].max(1e-9)),
        ]);
        comm_tbl.row(vec![
            names[i].into(),
            fmt_bytes(report.step_bytes[i]),
            fmt_bytes(off_bytes),
            format!("{:.1}x", off_bytes as f64 / report.step_bytes[i].max(1) as f64),
        ]);
    }
    time_tbl.print();
    comm_tbl.print();
    println!("\nshape check: the data-independent offline phase dominates every step,");
    println!("so the data-dependent online phase is near-plaintext fast (paper Q2).");
}
