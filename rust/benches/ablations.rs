//! Ablations beyond the paper's figures:
//!
//! * OU vs Paillier per-operation cost — the paper's §5.1 claim that OU
//!   "outperforms Paillier over all operations";
//! * PJRT (AOT Pallas artifact) vs native Rust ring matmul;
//! * Kogge-Stone secure-comparison lane throughput;
//! * garbled-circuit AND-gate throughput (garble + eval).

use ppkmeans::bench::{fmt_secs, time_reps, Table};
use ppkmeans::bigint::BigUint;
use ppkmeans::gc::builder::assign_circuit;
use ppkmeans::gc::garble::{evaluate, garble};
use ppkmeans::he::{ou::Ou, paillier::Paillier, HeScheme};
use ppkmeans::ring::matrix::Mat;
use ppkmeans::util::prng::Prg;
use ppkmeans::util::stats::mean;

fn he_ops<S: HeScheme>(bits: usize, name: &str, tbl: &mut Table) {
    let mut prg = Prg::new(1);
    let (pk, sk) = S::keygen(bits, &mut prg);
    let m = BigUint::from_u64(123456789);
    let enc = time_reps(2, 10, || {
        let _ = S::encrypt(&pk, &m, &mut prg);
    });
    let c = S::encrypt(&pk, &m, &mut prg);
    let dec = time_reps(2, 10, || {
        let _ = S::decrypt(&pk, &sk, &c);
    });
    let add = time_reps(2, 50, || {
        let _ = S::add(&pk, &c, &c);
    });
    let x = BigUint::from_u64(0xDEADBEEF);
    let smul = time_reps(2, 10, || {
        let _ = S::smul(&pk, &c, &x);
    });
    tbl.row(vec![
        name.into(),
        fmt_secs(mean(&enc)),
        fmt_secs(mean(&dec)),
        fmt_secs(mean(&add)),
        fmt_secs(mean(&smul)),
    ]);
}

fn main() {
    // ---- OU vs Paillier (same modulus size).
    let mut he = Table::new(
        "HE per-operation cost (1024-bit modulus)",
        &["scheme", "encrypt", "decrypt", "add", "smul(64b)"],
    );
    he_ops::<Ou>(1024, "Okamoto-Uchiyama", &mut he);
    he_ops::<Paillier>(1024, "Paillier", &mut he);
    he.print();
    println!("shape check: OU cheaper on every operation (paper §5.1).\n");

    // ---- PJRT vs native matmul (PJRT column needs `--features pjrt`
    // and built artifacts; otherwise the dispatch layer reports n/a).
    let mut mm = Table::new("ring matmul backends", &["shape", "native", "pjrt"]);
    let have_pjrt = ppkmeans::runtime::dispatch::init(std::path::Path::new("artifacts")).is_ok()
        && ppkmeans::runtime::dispatch::available();
    let mut prg = Prg::new(2);
    // Shapes stay above dispatch::DISPATCH_THRESHOLD so the "pjrt"
    // column really times the PJRT service, not the native fallback.
    for sz in [256usize, 512, 1024] {
        let a = Mat::random(sz, sz, &mut prg);
        let b = Mat::random(sz, sz, &mut prg);
        let native = time_reps(1, 3, || {
            let _ = a.matmul(&b);
        });
        let pjrt = if have_pjrt {
            // dispatch::matmul routes to the service above the threshold;
            // time it directly for an apples-to-apples per-shape figure.
            let t = time_reps(1, 3, || {
                let _ = ppkmeans::runtime::dispatch::matmul(&a, &b);
            });
            fmt_secs(mean(&t))
        } else {
            "n/a (add the xla dep + --features pjrt + make artifacts)".into()
        };
        mm.row(vec![format!("{sz}^3"), fmt_secs(mean(&native)), pjrt]);
    }
    mm.print();
    println!();

    // ---- Secure comparison throughput (the S2 hot gate).
    let mut cmp = Table::new("Kogge-Stone CMP throughput", &["lanes", "time", "lanes/s"]);
    for lanes in [1_000usize, 10_000, 100_000] {
        let x = Mat::random(1, lanes, &mut prg);
        let y = Mat::random(1, lanes, &mut prg);
        use ppkmeans::net::run_two_party;
        use ppkmeans::offline::dealer::Dealer;
        use ppkmeans::ss::{Session, SessionOptions, compare};
        let reps = 3;
        let t = time_reps(1, reps, || {
            let (x0, y0) = (x.clone(), y.clone());
            let (x1, y1) = (Mat::zeros(1, lanes), Mat::zeros(1, lanes));
            run_two_party(
                move |c| {
                    let mut ts = Dealer::new(5, 0);
                    let mut ctx = Session::new(c, &mut ts, Prg::new(1), SessionOptions::default());
                    compare::lt(&mut ctx, &x0, &y0);
                },
                move |c| {
                    let mut ts = Dealer::new(5, 1);
                    let mut ctx = Session::new(c, &mut ts, Prg::new(2), SessionOptions::default());
                    compare::lt(&mut ctx, &x1, &y1);
                },
            );
        });
        let secs = mean(&t);
        cmp.row(vec![
            format!("{lanes}"),
            fmt_secs(secs),
            format!("{:.0}", lanes as f64 / secs),
        ]);
    }
    cmp.print();
    println!();

    // ---- GC throughput.
    let circ = assign_circuit(5, 48);
    let ands = circ.and_count();
    let mut gprg = Prg::new(3);
    let t_garble = time_reps(1, 10, || {
        let _ = garble(&circ, &mut gprg);
    });
    let gb = garble(&circ, &mut gprg);
    let labels: Vec<u128> = {
        let mut v = vec![gb.labels(0).1];
        for i in 0..circ.n_garbler {
            v.push(gb.labels(circ.garbler_input(i)).0);
        }
        for i in 0..circ.n_eval {
            v.push(gb.labels(circ.eval_input(i)).0);
        }
        v
    };
    let t_eval = time_reps(1, 10, || {
        let _ = evaluate(&circ, &gb.tables, &labels);
    });
    let mut gc = Table::new("garbled circuit throughput (argmin k=5, w=48)", &["op", "time", "AND gates/s"]);
    gc.row(vec![
        "garble".into(),
        fmt_secs(mean(&t_garble)),
        format!("{:.0}", ands as f64 / mean(&t_garble)),
    ]);
    gc.row(vec![
        "evaluate".into(),
        fmt_secs(mean(&t_eval)),
        format!("{:.0}", ands as f64 / mean(&t_eval)),
    ]);
    gc.print();
}
