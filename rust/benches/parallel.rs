//! Multi-core runtime study: offline-prefill and online wall-clock
//! scaling across 1/2/4/8 worker threads, with the determinism
//! cross-check (identical flight/byte meters at every thread count).
//!
//! Claims under test (regression-tested in `rust/tests/parallel.rs`):
//!
//! * offline prefabrication is embarrassingly parallel — the dealer
//!   forks per-item child PRGs sequentially and expands them on the
//!   pool, so 4 workers should approach 4× on triple-heavy demands
//!   (the acceptance bar is ≥ 2×);
//! * the online phase's plaintext-side products scale with cores while
//!   the flight schedule stays byte-identical — same rounds, same
//!   bytes, lower wall-clock.
//!
//! Emits `BENCH_parallel.json` in the working directory.

use ppkmeans::bench::{fmt_secs, Table};
use ppkmeans::data::blobs::BlobSpec;
use ppkmeans::kmeans::config::{Partition, SecureKmeansConfig, TileFlights};
use ppkmeans::kmeans::secure;
use ppkmeans::offline::dealer::Dealer;
use ppkmeans::offline::store::{Demand, TripleStore};
use ppkmeans::runtime::pool::Parallelism;
use std::time::Instant;

const THREAD_COUNTS: [usize; 4] = [1, 2, 4, 8];

struct OfflineRow {
    threads: usize,
    secs: f64,
    speedup: f64,
}

struct OnlineRow {
    threads: usize,
    wall: f64,
    speedup: f64,
    online_rounds: u64,
    online_bytes: u64,
}

/// A training-shaped demand: tile-shaped matrix triples (the heavy
/// part — party 1 computes a real U·V per triple) plus the S2/S3 lane
/// chunks.
fn prefill_demand(tiles: usize, b: usize, d: usize, k: usize, iters: usize) -> Demand {
    let mut per_iter = Demand::default();
    for _ in 0..tiles {
        per_iter.mat(b, d, k);
        per_iter.mat(k, b, d);
        // Per-tile lane chunks (how the tiled online phase actually
        // records them) — the fan-out shards across chunks, so the
        // chunk granularity is the parallelism granularity.
        per_iter.vec_lanes(b * k);
        per_iter.bit_lanes(b * k * 64);
        per_iter.dabit_lanes(b * k);
    }
    per_iter.repeat(iters)
}

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let (n, d, k, iters, b) =
        if full { (20_000, 32, 4, 3, 512) } else { (4_000, 16, 4, 2, 256) };

    // ---- Offline: parallel prefill of a fixed demand. -------------
    let demand = prefill_demand(n / b, b, d, k, iters);
    let mut offline_rows = Vec::new();
    let mut base_secs = 0.0;
    for &threads in &THREAD_COUNTS {
        // Party 1 is the compute-heavy dealer side (it multiplies U·V).
        let mut store = TripleStore::new(Dealer::new(0xBE7C4, 1));
        let t0 = Instant::now();
        store.prefill_par(&demand, threads);
        let secs = t0.elapsed().as_secs_f64();
        if threads == 1 {
            base_secs = secs;
        }
        offline_rows.push(OfflineRow { threads, secs, speedup: base_secs / secs });
    }

    // ---- Online: full secure run at each thread count. ------------
    let mut spec = BlobSpec::new(n, d, k);
    spec.spread = 0.02;
    let data = spec.generate(7);
    let base = SecureKmeansConfig {
        k,
        iters,
        partition: Partition::Vertical { d_a: d / 2 },
        tile_rows: Some(b),
        tile_flights: TileFlights::Lockstep,
        ..Default::default()
    };
    let mut online_rows: Vec<OnlineRow> = Vec::new();
    for &threads in &THREAD_COUNTS {
        let cfg = SecureKmeansConfig {
            parallelism: Parallelism::new(threads),
            ..base.clone()
        };
        let out = secure::run(&data, &cfg).expect("run");
        let online = out.meter_a.total_prefix("online.");
        let wall = out.wall_secs;
        let speedup = online_rows.first().map(|r| r.wall / wall).unwrap_or(1.0);
        online_rows.push(OnlineRow {
            threads,
            wall,
            speedup,
            online_rounds: online.rounds,
            online_bytes: online.bytes_sent,
        });
    }

    // Determinism witness: the transcript must not move with threads.
    for r in &online_rows[1..] {
        assert_eq!(
            r.online_rounds, online_rows[0].online_rounds,
            "flight count must be thread-count independent"
        );
        assert_eq!(
            r.online_bytes, online_rows[0].online_bytes,
            "byte count must be thread-count independent"
        );
    }

    let mut tbl = Table::new(
        &format!("Offline prefill scaling — demand of {} mat triples (B={b}, d={d}, k={k})",
            demand.mats.iter().map(|&(_, c)| c).sum::<usize>()),
        &["threads", "prefill wall", "speedup"],
    );
    for r in &offline_rows {
        tbl.row(vec![
            format!("{}", r.threads),
            fmt_secs(r.secs),
            format!("{:.2}x", r.speedup),
        ]);
    }
    tbl.print();

    let mut tbl = Table::new(
        &format!("Online scaling — n={n}, d={d}, k={k}, t={iters} (vertical, lockstep B={b})"),
        &["threads", "wall", "speedup", "online rounds", "online bytes"],
    );
    for r in &online_rows {
        tbl.row(vec![
            format!("{}", r.threads),
            fmt_secs(r.wall),
            format!("{:.2}x", r.speedup),
            format!("{}", r.online_rounds),
            format!("{}", r.online_bytes),
        ]);
    }
    tbl.print();

    let four = offline_rows.iter().find(|r| r.threads == 4).expect("4-thread row");
    println!(
        "\noffline prefill at 4 threads: {:.2}x vs 1 thread (acceptance bar: >= 2x)",
        four.speedup
    );

    let mut json = String::from("{\n  \"bench\": \"parallel\",\n");
    json.push_str(&format!(
        "  \"config\": {{\"n\": {n}, \"d\": {d}, \"k\": {k}, \"iters\": {iters}, \"tile_rows\": {b}}},\n"
    ));
    json.push_str("  \"offline_prefill\": [\n");
    for (i, r) in offline_rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"threads\": {}, \"secs\": {:.6}, \"speedup\": {:.3}}}{}\n",
            r.threads,
            r.secs,
            r.speedup,
            if i + 1 < offline_rows.len() { "," } else { "" },
        ));
    }
    json.push_str("  ],\n  \"online\": [\n");
    for (i, r) in online_rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"threads\": {}, \"wall_secs\": {:.6}, \"speedup\": {:.3}, \
             \"online_rounds\": {}, \"online_bytes\": {}}}{}\n",
            r.threads,
            r.wall,
            r.speedup,
            r.online_rounds,
            r.online_bytes,
            if i + 1 < online_rows.len() { "," } else { "" },
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::write("BENCH_parallel.json", &json).expect("write BENCH_parallel.json");
    println!("wrote BENCH_parallel.json");
}
