//! Multi-core + packed-lane runtime study: offline-prefill and online
//! wall-clock scaling across 1/2/4/8 worker threads and 1/4/8 SIMD
//! lanes, with the determinism cross-checks (identical flight/byte
//! meters at every thread count and lane width, identical fabricated
//! material at every lane width).
//!
//! Claims under test (regression-tested in `rust/tests/parallel.rs` and
//! `rust/tests/lanes.rs`):
//!
//! * offline prefabrication is embarrassingly parallel — the dealer
//!   forks per-item child PRGs sequentially and expands them on the
//!   pool, so 4 workers should approach 4× on triple-heavy demands
//!   (the acceptance bar is ≥ 2×);
//! * the packed Speck counter-mode batches behind the dealer's bulk PRG
//!   draws break the per-block ARX dependency chain, so 8 lanes on one
//!   thread should beat the scalar path ≥ 2× on the same demand — and
//!   compose with the pool (4 threads × 8 lanes ≥ 1.5× the 4-thread
//!   scalar cell);
//! * the online phase's plaintext-side products scale with cores while
//!   the flight schedule stays byte-identical — same rounds, same
//!   bytes, lower wall-clock; lane width is equally transcript-neutral.
//!
//! Emits `BENCH_parallel.json` in the working directory.

use ppkmeans::bench::{fmt_secs, Table};
use ppkmeans::data::blobs::BlobSpec;
use ppkmeans::kmeans::config::{Partition, SecureKmeansConfig, TileFlights};
use ppkmeans::kmeans::secure;
use ppkmeans::offline::dealer::Dealer;
use ppkmeans::offline::store::{Demand, TripleStore};
use ppkmeans::runtime::pool::Parallelism;
use ppkmeans::runtime::simd::{set_global_lanes, Lanes};
use ppkmeans::ring::matrix::Mat;
use ppkmeans::ss::triples::TripleSource;
use std::time::Instant;

const THREAD_COUNTS: [usize; 4] = [1, 2, 4, 8];
const LANE_WIDTHS: [usize; 3] = [1, 4, 8];

struct OfflineRow {
    threads: usize,
    secs: f64,
    speedup: f64,
}

struct LanesOfflineRow {
    lanes: usize,
    threads: usize,
    secs: f64,
    /// Relative to the (threads = 1, lanes = 1) scalar reference cell.
    speedup: f64,
}

struct OnlineRow {
    threads: usize,
    wall: f64,
    speedup: f64,
    online_rounds: u64,
    online_bytes: u64,
}

struct LanesOnlineRow {
    lanes: usize,
    wall: f64,
    online_rounds: u64,
    online_bytes: u64,
}

/// A training-shaped demand: tile-shaped matrix triples (the heavy
/// part — party 1 computes a real U·V per triple) plus the S2/S3 lane
/// chunks.
fn prefill_demand(tiles: usize, b: usize, d: usize, k: usize, iters: usize) -> Demand {
    let mut per_iter = Demand::default();
    for _ in 0..tiles {
        per_iter.mat(b, d, k);
        per_iter.mat(k, b, d);
        // Per-tile lane chunks (how the tiled online phase actually
        // records them) — the fan-out shards across chunks, so the
        // chunk granularity is the parallelism granularity.
        per_iter.vec_lanes(b * k);
        per_iter.bit_lanes(b * k * 64);
        per_iter.dabit_lanes(b * k);
    }
    per_iter.repeat(iters)
}

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let (n, d, k, iters, b) =
        if full { (20_000, 32, 4, 3, 512) } else { (4_000, 16, 4, 2, 256) };

    // ---- Offline: parallel prefill of a fixed demand. -------------
    let demand = prefill_demand(n / b, b, d, k, iters);
    let mut offline_rows = Vec::new();
    let mut base_secs = 0.0;
    for &threads in &THREAD_COUNTS {
        // Party 1 is the compute-heavy dealer side (it multiplies U·V).
        let mut store = TripleStore::new(Dealer::new(0xBE7C4, 1));
        let t0 = Instant::now();
        store.prefill_par(&demand, threads);
        let secs = t0.elapsed().as_secs_f64();
        if threads == 1 {
            base_secs = secs;
        }
        offline_rows.push(OfflineRow { threads, secs, speedup: base_secs / secs });
    }

    // ---- Offline: the lanes × threads grid on the same demand. ----
    // The fabricated material must be bit-identical in every cell (the
    // simd determinism contract) — witnessed on the first stocked
    // matrix triple of each prefilled store.
    let mut lanes_rows: Vec<LanesOfflineRow> = Vec::new();
    let mut scalar_cell = 0.0;
    let mut witness: Option<(Mat, Mat, Mat)> = None;
    for &threads in &[1usize, 4] {
        for &lanes in &LANE_WIDTHS {
            set_global_lanes(lanes);
            let mut store = TripleStore::new(Dealer::new(0xBE7C4, 1));
            let t0 = Instant::now();
            store.prefill_par(&demand, threads);
            let secs = t0.elapsed().as_secs_f64();
            set_global_lanes(1);
            let t = store.mat_triple(b, d, k);
            match &witness {
                None => witness = Some((t.u, t.v, t.z)),
                Some((u, v, z)) => {
                    assert_eq!(&t.u, u, "U must be lane/thread independent");
                    assert_eq!(&t.v, v, "V must be lane/thread independent");
                    assert_eq!(&t.z, z, "Z must be lane/thread independent");
                }
            }
            if threads == 1 && lanes == 1 {
                scalar_cell = secs;
            }
            lanes_rows.push(LanesOfflineRow {
                lanes,
                threads,
                secs,
                speedup: scalar_cell / secs,
            });
        }
    }

    // ---- Online: full secure run at each thread count. ------------
    let mut spec = BlobSpec::new(n, d, k);
    spec.spread = 0.02;
    let data = spec.generate(7);
    let base = SecureKmeansConfig {
        k,
        iters,
        partition: Partition::Vertical { d_a: d / 2 },
        tile_rows: Some(b),
        tile_flights: TileFlights::Lockstep,
        ..Default::default()
    };
    let mut online_rows: Vec<OnlineRow> = Vec::new();
    for &threads in &THREAD_COUNTS {
        let cfg = SecureKmeansConfig {
            parallelism: Parallelism::new(threads),
            ..base.clone()
        };
        let out = secure::run(&data, &cfg).expect("run");
        let online = out.meter_a.total_prefix("online.");
        let wall = out.wall_secs;
        let speedup = online_rows.first().map(|r| r.wall / wall).unwrap_or(1.0);
        online_rows.push(OnlineRow {
            threads,
            wall,
            speedup,
            online_rounds: online.rounds,
            online_bytes: online.bytes_sent,
        });
    }

    // Determinism witness: the transcript must not move with threads.
    for r in &online_rows[1..] {
        assert_eq!(
            r.online_rounds, online_rows[0].online_rounds,
            "flight count must be thread-count independent"
        );
        assert_eq!(
            r.online_bytes, online_rows[0].online_bytes,
            "byte count must be thread-count independent"
        );
    }

    // ---- Online: full secure run at each lane width (one thread). --
    // Lane width must be transcript-neutral: identical centroids and
    // identical meters, only wall-clock moves.
    let mut lanes_online: Vec<LanesOnlineRow> = Vec::new();
    let mut lanes_centroids: Option<Vec<f64>> = None;
    for &lanes in &LANE_WIDTHS {
        let cfg = SecureKmeansConfig { lanes: Lanes::new(lanes), ..base.clone() };
        let out = secure::run(&data, &cfg).expect("run");
        set_global_lanes(1);
        let online = out.meter_a.total_prefix("online.");
        match &lanes_centroids {
            None => lanes_centroids = Some(out.centroids.clone()),
            Some(c) => assert_eq!(
                &out.centroids, c,
                "centroids must be lane-width independent"
            ),
        }
        lanes_online.push(LanesOnlineRow {
            lanes,
            wall: out.wall_secs,
            online_rounds: online.rounds,
            online_bytes: online.bytes_sent,
        });
    }
    for r in &lanes_online[1..] {
        assert_eq!(
            (r.online_rounds, r.online_bytes),
            (lanes_online[0].online_rounds, lanes_online[0].online_bytes),
            "meters must be lane-width independent"
        );
    }

    let mut tbl = Table::new(
        &format!("Offline prefill scaling — demand of {} mat triples (B={b}, d={d}, k={k})",
            demand.mats.iter().map(|&(_, c)| c).sum::<usize>()),
        &["threads", "prefill wall", "speedup"],
    );
    for r in &offline_rows {
        tbl.row(vec![
            format!("{}", r.threads),
            fmt_secs(r.secs),
            format!("{:.2}x", r.speedup),
        ]);
    }
    tbl.print();

    let mut tbl = Table::new(
        &format!("Online scaling — n={n}, d={d}, k={k}, t={iters} (vertical, lockstep B={b})"),
        &["threads", "wall", "speedup", "online rounds", "online bytes"],
    );
    for r in &online_rows {
        tbl.row(vec![
            format!("{}", r.threads),
            fmt_secs(r.wall),
            format!("{:.2}x", r.speedup),
            format!("{}", r.online_rounds),
            format!("{}", r.online_bytes),
        ]);
    }
    tbl.print();

    let mut tbl = Table::new(
        "Offline prefill — lanes x threads grid (speedup vs 1-thread scalar cell)",
        &["threads", "lanes", "prefill wall", "speedup"],
    );
    for r in &lanes_rows {
        tbl.row(vec![
            format!("{}", r.threads),
            format!("{}", r.lanes),
            fmt_secs(r.secs),
            format!("{:.2}x", r.speedup),
        ]);
    }
    tbl.print();

    let mut tbl = Table::new(
        "Online lane-width sweep (1 thread) — transcript must not move",
        &["lanes", "wall", "online rounds", "online bytes"],
    );
    for r in &lanes_online {
        tbl.row(vec![
            format!("{}", r.lanes),
            fmt_secs(r.wall),
            format!("{}", r.online_rounds),
            format!("{}", r.online_bytes),
        ]);
    }
    tbl.print();

    let four = offline_rows.iter().find(|r| r.threads == 4).expect("4-thread row");
    println!(
        "\noffline prefill at 4 threads: {:.2}x vs 1 thread (acceptance bar: >= 2x)",
        four.speedup
    );

    let cell = |threads: usize, lanes: usize| {
        lanes_rows
            .iter()
            .find(|r| r.threads == threads && r.lanes == lanes)
            .expect("grid cell")
    };
    println!(
        "offline prefill at 1 thread x 8 lanes: {:.2}x vs scalar (acceptance bar: >= 2x)",
        cell(1, 1).secs / cell(1, 8).secs
    );
    println!(
        "offline prefill at 4 threads x 8 lanes: {:.2}x vs 4-thread scalar (acceptance bar: >= 1.5x)",
        cell(4, 1).secs / cell(4, 8).secs
    );

    let mut json = String::from("{\n  \"bench\": \"parallel\",\n");
    json.push_str(&format!(
        "  \"config\": {{\"n\": {n}, \"d\": {d}, \"k\": {k}, \"iters\": {iters}, \"tile_rows\": {b}}},\n"
    ));
    json.push_str("  \"offline_prefill\": [\n");
    for (i, r) in offline_rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"threads\": {}, \"secs\": {:.6}, \"speedup\": {:.3}}}{}\n",
            r.threads,
            r.secs,
            r.speedup,
            if i + 1 < offline_rows.len() { "," } else { "" },
        ));
    }
    json.push_str("  ],\n  \"online\": [\n");
    for (i, r) in online_rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"threads\": {}, \"wall_secs\": {:.6}, \"speedup\": {:.3}, \
             \"online_rounds\": {}, \"online_bytes\": {}}}{}\n",
            r.threads,
            r.wall,
            r.speedup,
            r.online_rounds,
            r.online_bytes,
            if i + 1 < online_rows.len() { "," } else { "" },
        ));
    }
    json.push_str("  ],\n  \"offline_prefill_lanes\": [\n");
    for (i, r) in lanes_rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"lanes\": {}, \"threads\": {}, \"secs\": {:.6}, \"speedup\": {:.3}}}{}\n",
            r.lanes,
            r.threads,
            r.secs,
            r.speedup,
            if i + 1 < lanes_rows.len() { "," } else { "" },
        ));
    }
    json.push_str("  ],\n  \"online_lanes\": [\n");
    for (i, r) in lanes_online.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"lanes\": {}, \"wall_secs\": {:.6}, \
             \"online_rounds\": {}, \"online_bytes\": {}}}{}\n",
            r.lanes,
            r.wall,
            r.online_rounds,
            r.online_bytes,
            if i + 1 < lanes_online.len() { "," } else { "" },
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::write("BENCH_parallel.json", &json).expect("write BENCH_parallel.json");
    println!("wrote BENCH_parallel.json");
}
