//! Row-tiling study: tiled vs monolithic schedules on wall-clock, online
//! flight count, and offline triple footprint.
//!
//! The claims under test (and regression-tested in
//! `rust/tests/round_counts.rs`):
//!
//! * `TileFlights::Lockstep` costs **zero** extra flights over the
//!   monolithic schedule while bounding every matrix triple by the tile
//!   size B — the peak triple bytes column collapses;
//! * `TileFlights::Streamed` pays rounds × tiles for O(B·d) live state;
//! * tiled offline demand contains no n-sized matrix shape, so one
//!   prefill recipe serves any dataset size.
//!
//! Emits `BENCH_tiling.json` next to the working directory for the
//! tracking harness.

use ppkmeans::bench::{fmt_bytes, fmt_secs, Table};
use ppkmeans::data::blobs::BlobSpec;
use ppkmeans::kmeans::config::{Partition, SecureKmeansConfig, TileFlights};
use ppkmeans::kmeans::secure;

struct Row {
    schedule: String,
    wall: f64,
    online_rounds: u64,
    online_bytes: u64,
    peak_triple_bytes: u64,
    mat_triple_bytes: u64,
    max_mat_dim: usize,
}

fn run_one(
    data: &ppkmeans::data::blobs::Dataset,
    base: &SecureKmeansConfig,
    label: &str,
    tile_rows: Option<usize>,
    flights: TileFlights,
) -> Row {
    let cfg = SecureKmeansConfig { tile_rows, tile_flights: flights, ..base.clone() };
    let out = secure::run(data, &cfg).expect("run");
    let online = out.meter_a.total_prefix("online.");
    let max_mat_dim =
        out.demand.mats.iter().map(|&((m, k, n), _)| m.max(k).max(n)).max().unwrap_or(0);
    Row {
        schedule: label.to_string(),
        wall: out.wall_secs,
        online_rounds: online.rounds,
        online_bytes: online.bytes_sent,
        peak_triple_bytes: out.demand.peak_mat_triple_bytes(),
        mat_triple_bytes: out.demand.mat_triple_bytes(),
        max_mat_dim,
    }
}

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let (n, d, k) = if full { (20_000, 8, 4) } else { (2_000, 6, 3) };
    let iters = if full { 5 } else { 2 };
    let b = if full { 1024 } else { 128 };
    let mut spec = BlobSpec::new(n, d, k);
    spec.spread = 0.02;
    let data = spec.generate(7);
    let base = SecureKmeansConfig {
        k,
        iters,
        partition: Partition::Vertical { d_a: d / 2 },
        ..Default::default()
    };

    let rows = vec![
        run_one(&data, &base, "monolithic", None, TileFlights::Lockstep),
        run_one(&data, &base, &format!("lockstep B={b}"), Some(b), TileFlights::Lockstep),
        run_one(&data, &base, &format!("streamed B={b}"), Some(b), TileFlights::Streamed),
    ];

    let mut tbl = Table::new(
        &format!("Row tiling — n={n}, d={d}, k={k}, t={iters} (vertical, Beaver)"),
        &["schedule", "wall", "online rounds", "online bytes", "peak triple", "mat triples", "max mat dim"],
    );
    for r in &rows {
        tbl.row(vec![
            r.schedule.clone(),
            fmt_secs(r.wall),
            format!("{}", r.online_rounds),
            fmt_bytes(r.online_bytes),
            fmt_bytes(r.peak_triple_bytes),
            fmt_bytes(r.mat_triple_bytes),
            format!("{}", r.max_mat_dim),
        ]);
    }
    tbl.print();

    // Shape checks the table should witness.
    assert_eq!(
        rows[0].online_rounds, rows[1].online_rounds,
        "lockstep tiling must add zero flights"
    );
    assert!(
        rows[1].peak_triple_bytes < rows[0].peak_triple_bytes,
        "tiling must shrink the peak triple"
    );
    assert!(rows[1].max_mat_dim <= b.max(d).max(k), "tiled shapes must be B-bounded");

    let mut json = String::from("{\n  \"bench\": \"tiling\",\n");
    json.push_str(&format!(
        "  \"config\": {{\"n\": {n}, \"d\": {d}, \"k\": {k}, \"iters\": {iters}, \"tile_rows\": {b}}},\n"
    ));
    json.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"schedule\": \"{}\", \"wall_secs\": {:.6}, \"online_rounds\": {}, \
             \"online_bytes\": {}, \"peak_mat_triple_bytes\": {}, \"mat_triple_bytes\": {}, \
             \"max_mat_dim\": {}}}{}\n",
            r.schedule,
            r.wall,
            r.online_rounds,
            r.online_bytes,
            r.peak_triple_bytes,
            r.mat_triple_bytes,
            r.max_mat_dim,
            if i + 1 < rows.len() { "," } else { "" },
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::write("BENCH_tiling.json", &json).expect("write BENCH_tiling.json");
    println!("\nwrote BENCH_tiling.json");
}
