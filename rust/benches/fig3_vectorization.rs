//! Figure 3 — vectorization study on the distance step (WAN; n = 1000,
//! k = 4, t = 20, d ∈ {2, 4, 6, 8}).
//!
//! Compares the matrix-form F'_ESD (Eq. 3 — one Beaver reveal per cross
//! product) against the pre-vectorization numeric baseline (one scalar
//! protocol per (sample, centroid) pair → n·k rounds). On WAN the round
//! count dominates, so the gap is the paper's headline: vectorized time
//! grows slowly with d while the naive path is orders of magnitude
//! slower, and the gain grows with d.

use ppkmeans::bench::{fmt_secs, Table};
use ppkmeans::coordinator::Report;
use ppkmeans::data::blobs::BlobSpec;
use ppkmeans::kmeans::config::{EsdMode, Partition, SecureKmeansConfig};
use ppkmeans::kmeans::secure;
use ppkmeans::net::cost::CostModel;
use ppkmeans::offline::pricing;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let (n, k) = (1000usize, 4usize);
    let iters = if full { 20 } else { 3 };
    let wan = CostModel::wan();
    println!("calibrating OT generator...");
    let cal = pricing::calibrate();

    let mut tbl = Table::new(
        &format!("Fig 3 — S1 distance step, naive vs vectorized (WAN, n={n}, k={k}, t={iters})"),
        &["d", "vec online", "vec offline", "naive online", "naive offline", "speedup(online)"],
    );

    for d in [2usize, 4, 6, 8] {
        let ds = BlobSpec::new(n, d, k).generate(3);
        let mk_cfg = |esd: EsdMode| SecureKmeansConfig {
            k,
            iters,
            esd,
            partition: Partition::Vertical { d_a: d / 2 },
            ..Default::default()
        };
        let v = secure::run(&ds, &mk_cfg(EsdMode::Vectorized)).expect("vec");
        let nv = secure::run(&ds, &mk_cfg(EsdMode::Naive)).expect("naive");
        let rv = Report::from_run(&v, &wan, &cal);
        let rn = Report::from_run(&nv, &wan, &cal);
        // S1 figures only (the step the paper plots).
        let v_on = rv.steps[0];
        let n_on = rn.steps[0];
        let v_off = pricing::offline_secs(&v.step_demands[0], &cal);
        let n_off = pricing::offline_secs(&nv.step_demands[0], &cal);
        tbl.row(vec![
            format!("{d}"),
            fmt_secs(v_on),
            fmt_secs(v_off),
            fmt_secs(n_on),
            fmt_secs(n_off),
            format!("{:.0}x", n_on / v_on.max(1e-9)),
        ]);
    }
    tbl.print();
    println!("\nshape checks: online speedup grows with d; vectorized time increases");
    println!("slowly with d while naive pays n·k WAN rounds regardless of d (paper Q3).");
}
