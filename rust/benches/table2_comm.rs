//! Table 2 — communication size (MB) vs M-Kmeans (d = 2, t = 10,
//! l = 64). Byte counts are exact at any scale: every protocol message
//! is really serialized and metered; offline bytes come from the IKNP/
//! Gilboa formulas validated against the real generator
//! (`offline::pricing`).
//!
//! Paper reference rows (MB): (10^4,2): 1084/3660/4744 vs 5118;
//! (10^4,5): 3156/12900/16056 vs 18632; (10^5,2): 14147/32598/46745 vs
//! 47342; (10^5,5): 33572/131243/164815 vs 192192.

use ppkmeans::bench::{fmt_bytes, Table};
use ppkmeans::data::blobs::BlobSpec;
use ppkmeans::kmeans::config::{Partition, SecureKmeansConfig};
use ppkmeans::kmeans::secure;
use ppkmeans::mkmeans::{self, MkmeansConfig};
use ppkmeans::offline::pricing;

const MK_CAP: usize = 1_000;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let ns: &[usize] = if full { &[10_000, 100_000] } else { &[1_000, 4_000] };
    let ks = [2usize, 5];
    let (d, iters) = (2usize, 10usize);

    let mut table = Table::new(
        "Table 2 — communication (d=2, t=10, l=64), both parties summed",
        &["n", "k", "ours online", "ours offline", "ours total", "M-Kmeans"],
    );

    for &n in ns {
        for &k in &ks {
            let ds = BlobSpec::new(n, d, k).generate(1);
            let cfg = SecureKmeansConfig {
                k,
                iters,
                partition: Partition::Vertical { d_a: 1 },
                ..Default::default()
            };
            let out = secure::run(&ds, &cfg).expect("ours");
            let online = out.meter_a.total_prefix("online.").bytes_sent
                + out.meter_b.total_prefix("online.").bytes_sent;
            let offline = pricing::offline_bytes(&out.demand);

            let mk_n = n.min(MK_CAP);
            let mk_ds = BlobSpec::new(mk_n, d, k).generate(1);
            let mcfg = MkmeansConfig { k, iters, seed: cfg.seed, d_a: 1 };
            let mk = mkmeans::run_vertical(&mk_ds, &mcfg).expect("mkmeans");
            let mk_bytes = (mk.bytes_total as f64 * n as f64 / mk_n as f64) as u64;

            table.row(vec![
                format!("{n}"),
                format!("{k}"),
                fmt_bytes(online),
                fmt_bytes(offline),
                fmt_bytes(online + offline),
                format!("{}{}", fmt_bytes(mk_bytes), if mk_n < n { "*" } else { "" }),
            ]);
        }
    }
    table.print();
    println!("\n(*) M-Kmeans measured at n={MK_CAP} and scaled linearly.");
    println!("shape checks: ours-online ≪ M-Kmeans total; totals same order of magnitude.");
}
