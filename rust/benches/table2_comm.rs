//! Table 2 — communication size (MB) vs M-Kmeans (d = 2, t = 10,
//! l = 64). Byte counts are exact at any scale: every protocol message
//! is really serialized and metered — the online column is a
//! **measurement**, not a model — while offline bytes come from the
//! IKNP/Gilboa formulas validated against the real generator
//! (`offline::pricing`). `--smoke` runs the CI quick grid; counts land
//! in `BENCH_table2_comm.json` and are pinned by the goldens in
//! `rust/tests/goldens/`.
//!
//! Paper reference rows (MB): (10^4,2): 1084/3660/4744 vs 5118;
//! (10^4,5): 3156/12900/16056 vs 18632; (10^5,2): 14147/32598/46745 vs
//! 47342; (10^5,5): 33572/131243/164815 vs 192192.

use ppkmeans::bench::{fmt_bytes, train_counts, train_malicious_counts, Table};
use ppkmeans::data::blobs::BlobSpec;
use ppkmeans::mkmeans::{self, MkmeansConfig};

const MK_CAP: usize = 1_000;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let smoke = std::env::args().any(|a| a == "--smoke");
    let ns: &[usize] = if full {
        &[10_000, 100_000]
    } else if smoke {
        &[256]
    } else {
        &[1_000, 4_000]
    };
    let ks = [2usize, 5];
    let d = 2usize;
    let iters = if smoke { 3 } else { 10 };

    // The malicious tier's byte surcharge is O(1) per phase boundary
    // (96 B/party/barrier + 32 B/party per final opening), independent
    // of n/d/k — measured once, annotated on every row.
    let mc = train_malicious_counts(256, d, 2, iters);

    let mut table = Table::new(
        "Table 2 — communication (d=2, t=10, l=64), both parties summed",
        &["n", "k", "ours online", "ours offline", "ours total", "malicious Δ", "M-Kmeans"],
    );
    let mut rows_json: Vec<String> = Vec::new();

    for &n in ns {
        for &k in &ks {
            let c = train_counts(n, d, k, iters);
            let (online, offline) = (c.online_bytes, c.offline_bytes);

            let mk_bytes = if smoke {
                None
            } else {
                let mk_n = n.min(MK_CAP);
                let mk_ds = BlobSpec::new(mk_n, d, k).generate(1);
                let mcfg = MkmeansConfig { k, iters, seed: 0xBEEF, d_a: 1 };
                let mk = mkmeans::run_vertical(&mk_ds, &mcfg).expect("mkmeans");
                Some(((mk.bytes_total as f64 * n as f64 / mk_n as f64) as u64, mk_n < n))
            };

            table.row(vec![
                format!("{n}"),
                format!("{k}"),
                fmt_bytes(online),
                fmt_bytes(offline),
                fmt_bytes(online + offline),
                format!("+{}", fmt_bytes(mc.extra_bytes())),
                match mk_bytes {
                    Some((b, scaled)) => {
                        format!("{}{}", fmt_bytes(b), if scaled { "*" } else { "" })
                    }
                    None => "-".into(),
                },
            ]);
            rows_json.push(format!(
                "    {{\"n\": {n}, \"k\": {k}, \"iters\": {iters}, \
                 \"measured\": {{\"online_bytes\": {online}, \"online_rounds\": {}, \
                 \"s1_bytes\": {}, \"s2_bytes\": {}, \"s3_bytes\": {}}}, \
                 \"modeled\": {{\"offline_bytes\": {offline}}}, \
                 \"malicious\": {{\"mac_barrier_bytes\": {}, \"mac_barrier_rounds\": {}, \
                 \"reveal_extra_bytes\": {}, \"extra_bytes\": {}}}, \
                 \"total_bytes\": {}, \"mkmeans_bytes\": {}}}",
                c.online_rounds,
                c.step_bytes[0],
                c.step_bytes[1],
                c.step_bytes[2],
                mc.mac_barrier_bytes,
                mc.mac_barrier_rounds,
                mc.reveal_extra_bytes,
                mc.extra_bytes(),
                online + offline,
                mk_bytes.map(|(b, _)| b.to_string()).unwrap_or_else(|| "null".into()),
            ));
        }
    }
    table.print();
    if !smoke {
        println!("\n(*) M-Kmeans measured at n={MK_CAP} and scaled linearly.");
    }
    println!("shape checks: ours-online ≪ M-Kmeans total; totals same order of magnitude.");

    let mode = if full {
        "full"
    } else if smoke {
        "smoke"
    } else {
        "default"
    };
    let json = format!(
        "{{\n  \"bench\": \"table2_comm\",\n  \"mode\": \"{mode}\",\n  \"rows\": [\n{}\n  ]\n}}\n",
        rows_json.join(",\n")
    );
    match std::fs::write("BENCH_table2_comm.json", &json) {
        Ok(()) => println!("wrote BENCH_table2_comm.json"),
        Err(e) => eprintln!("could not write BENCH_table2_comm.json: {e}"),
    }
}
